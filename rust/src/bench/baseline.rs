//! Baseline snapshots and the regression verdict: persistent metric
//! documents (`BENCH_<experiment>.json`, `bench/BASELINE.json`), and
//! the comparison that turns *baseline vs current* into a per-metric
//! verdict table with a statistically gated pass/fail.
//!
//! A **metric** is one measured scalar (`mean ± ci95` over the
//! protocol's K iterations, plus the sample std and count that make
//! Welch's t-test possible later). A **document** is a platform-stamped
//! set of metrics. The **baseline** is a committed document; comparing
//! current documents against it yields [`Verdict`]s:
//!
//! * `Improved` / `Regressed` — Welch-significant at 95% *and* the
//!   relative effect exceeds the `min_effect_pct` floor (statistical
//!   significance alone flags microscopic-but-real shifts; the floor
//!   keeps the gate about regressions that matter).
//! * `Unchanged` — comparable, but not significant or below the floor.
//! * `PlatformSkip` — the platform fingerprints differ; numbers from
//!   different machines are not comparable and are never gated.
//! * `NoBaseline` — a new metric; recorded, not judged.
//! * `Insufficient` — degenerate statistics (fewer than two samples on
//!   either side), surfaced explicitly instead of as a `NaN` verdict.
//!   Zero-variance pairs are different: both sides deterministic means
//!   exact comparison is *stronger* than a t-test, so those are judged
//!   by mean equality against the effect floor and can gate (this is
//!   how size metrics like `artifact/bytes_per_weight` stay honest).
//!
//! Only metrics marked `gate` (the hot paths: batch kernel throughput,
//! shard scaling, HTTP p99, loadgen latency) can fail the gate, and an
//! `advisory` baseline (committed before any reference numbers were
//! recorded) disarms it entirely.

use super::env::Platform;
use super::stats::{welch_t_test, StatError, Summary};
use crate::coordinator::net::Json;
use std::path::Path;

/// One persisted measurement: identity, direction, gate flag, and the
/// summary statistics needed to compare it against another run.
#[derive(Clone, Debug, PartialEq)]
pub struct Metric {
    /// Experiment the metric belongs to (`batch`, `shard`, `http`, …).
    pub experiment: String,
    /// Stable metric key within the experiment (baseline matching is
    /// by `(experiment, name)` — renaming a metric orphans its
    /// baseline entry).
    pub name: String,
    /// Human unit (`samples/s`, `us`, `ns/hook`, …).
    pub unit: String,
    /// Whether larger values are better (throughput) or worse
    /// (latency).
    pub higher_is_better: bool,
    /// Hot-path marker: only gated metrics can fail `bench-compare`.
    pub gate: bool,
    /// Mean over the kept (outlier-filtered) iterations.
    pub mean: f64,
    /// Student-t 95% CI half-width (0 when `iterations < 2`).
    pub ci95: f64,
    /// Unbiased sample standard deviation (0 when `iterations < 2`).
    pub std: f64,
    /// Kept measured iterations.
    pub iterations: u64,
    /// Warmup invocations that preceded measurement.
    pub warmup: u64,
}

impl Metric {
    /// The summary view Welch's test consumes.
    fn summary(&self) -> Summary {
        Summary { n: self.iterations, mean: self.mean, std: self.std, min: self.mean, max: self.mean }
    }

    /// Serialize one metric.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("experiment".into(), Json::Str(self.experiment.clone())),
            ("name".into(), Json::Str(self.name.clone())),
            ("unit".into(), Json::Str(self.unit.clone())),
            ("higher_is_better".into(), Json::Bool(self.higher_is_better)),
            ("gate".into(), Json::Bool(self.gate)),
            ("mean".into(), Json::Num(self.mean)),
            ("ci95".into(), Json::Num(self.ci95)),
            ("std".into(), Json::Num(self.std)),
            ("iterations".into(), Json::Num(self.iterations as f64)),
            ("warmup".into(), Json::Num(self.warmup as f64)),
        ])
    }

    /// Parse one metric; the error names the missing/mistyped field.
    pub fn from_json(v: &Json) -> Result<Metric, String> {
        let text = |key: &str| {
            v.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("metric missing string field '{key}'"))
        };
        let num = |key: &str| match v.get(key) {
            Some(Json::Num(n)) => Ok(*n),
            _ => Err(format!("metric missing numeric field '{key}'")),
        };
        let flag = |key: &str| match v.get(key) {
            Some(Json::Bool(b)) => Ok(*b),
            _ => Err(format!("metric missing boolean field '{key}'")),
        };
        Ok(Metric {
            experiment: text("experiment")?,
            name: text("name")?,
            unit: text("unit")?,
            higher_is_better: flag("higher_is_better")?,
            gate: flag("gate")?,
            mean: num("mean")?,
            ci95: num("ci95")?,
            std: num("std")?,
            iterations: num("iterations")?.max(0.0) as u64,
            warmup: num("warmup")?.max(0.0) as u64,
        })
    }
}

/// A platform-stamped set of metrics: the shape of every
/// `BENCH_<experiment>.json`, of the merged `--baseline-out` candidate,
/// and of the committed `bench/BASELINE.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchDoc {
    /// Experiment name for single-experiment docs; `None` for merged
    /// baseline documents.
    pub experiment: Option<String>,
    /// An advisory baseline carries no recorded reference numbers yet
    /// (or was explicitly marked informational): comparisons render,
    /// the gate never fails. Recording a real baseline clears it.
    pub advisory: bool,
    /// Free-form provenance note.
    pub note: Option<String>,
    /// Machine that produced the numbers.
    pub platform: Option<Platform>,
    /// The measurements.
    pub metrics: Vec<Metric>,
}

impl BenchDoc {
    /// Serialize to a JSON document (newline-terminated).
    pub fn to_json_string(&self) -> String {
        let mut fields = vec![("version".to_string(), Json::Num(1.0))];
        if let Some(e) = &self.experiment {
            fields.push(("experiment".into(), Json::Str(e.clone())));
        }
        fields.push(("advisory".into(), Json::Bool(self.advisory)));
        if let Some(n) = &self.note {
            fields.push(("note".into(), Json::Str(n.clone())));
        }
        fields.push((
            "platform".into(),
            self.platform.as_ref().map(Platform::to_json).unwrap_or(Json::Null),
        ));
        fields
            .push(("metrics".into(), Json::Arr(self.metrics.iter().map(Metric::to_json).collect())));
        let mut text = Json::Obj(fields).render();
        text.push('\n');
        text
    }

    /// Parse a document.
    pub fn parse(text: &str) -> Result<BenchDoc, String> {
        let v = Json::parse(text.trim())?;
        let metrics = match v.get("metrics") {
            Some(Json::Arr(items)) => items
                .iter()
                .enumerate()
                .map(|(i, m)| Metric::from_json(m).map_err(|e| format!("metrics[{i}]: {e}")))
                .collect::<Result<Vec<_>, _>>()?,
            Some(_) => return Err("'metrics' is not an array".into()),
            None => Vec::new(),
        };
        Ok(BenchDoc {
            experiment: v.get("experiment").and_then(Json::as_str).map(str::to_string),
            advisory: matches!(v.get("advisory"), Some(Json::Bool(true))),
            note: v.get("note").and_then(Json::as_str).map(str::to_string),
            platform: v.get("platform").and_then(Platform::from_json),
            metrics,
        })
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<BenchDoc, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        BenchDoc::parse(&text).map_err(|e| format!("parse {}: {e}", path.display()))
    }

    /// Write to a file.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json_string())
    }
}

/// Per-metric comparison outcome.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// Significant, in the good direction, above the effect floor.
    Improved,
    /// Comparable; no significant shift above the floor.
    Unchanged,
    /// Significant, in the bad direction, above the effect floor.
    Regressed,
    /// No baseline entry with this `(experiment, name)`.
    NoBaseline,
    /// Platform fingerprints differ — not comparable, never gated.
    PlatformSkip,
    /// Statistics too degenerate for a verdict (the payload says why).
    Insufficient(StatError),
}

impl Verdict {
    /// Fixed-width-friendly label for the verdict table.
    pub fn label(&self) -> &'static str {
        match self {
            Verdict::Improved => "IMPROVED",
            Verdict::Unchanged => "unchanged",
            Verdict::Regressed => "REGRESSED",
            Verdict::NoBaseline => "new (no baseline)",
            Verdict::PlatformSkip => "SKIP (platform)",
            Verdict::Insufficient(StatError::TooFewSamples) => "insufficient (n<2)",
            Verdict::Insufficient(StatError::ZeroVariance) => "insufficient (zero variance)",
        }
    }
}

/// One row of the verdict table.
#[derive(Clone, Debug)]
pub struct Row {
    /// Experiment key.
    pub experiment: String,
    /// Metric key.
    pub name: String,
    /// Gate flag (from the *current* metric — the code being shipped
    /// decides what its hot paths are).
    pub gate: bool,
    /// Baseline `(mean, ci95)`, when an entry exists.
    pub base: Option<(f64, f64)>,
    /// Current `(mean, ci95)`.
    pub cur: (f64, f64),
    /// Relative change in percent, when comparable.
    pub delta_pct: Option<f64>,
    /// Welch t statistic, when computed.
    pub t: Option<f64>,
    /// The outcome.
    pub verdict: Verdict,
}

/// The full baseline-vs-current comparison.
#[derive(Clone, Debug)]
pub struct Comparison {
    /// Per-metric rows, in current-document order.
    pub rows: Vec<Row>,
    /// Whether the baseline was advisory (gate disarmed).
    pub advisory: bool,
    /// Effect-size floor (percent) used for Improved/Regressed calls.
    pub min_effect_pct: f64,
}

/// Compare current documents against a baseline. `min_effect_pct` is
/// the relative-change floor below which significant shifts still
/// count as `Unchanged`.
pub fn compare(baseline: &BenchDoc, currents: &[BenchDoc], min_effect_pct: f64) -> Comparison {
    let base_fp = baseline.platform.as_ref().map(Platform::fingerprint);
    let mut rows = Vec::new();
    for doc in currents {
        let cur_fp = doc.platform.as_ref().map(Platform::fingerprint);
        for m in &doc.metrics {
            let base_m = baseline
                .metrics
                .iter()
                .find(|b| b.experiment == m.experiment && b.name == m.name);
            let mut row = Row {
                experiment: m.experiment.clone(),
                name: m.name.clone(),
                gate: m.gate,
                base: base_m.map(|b| (b.mean, b.ci95)),
                cur: (m.mean, m.ci95),
                delta_pct: None,
                t: None,
                verdict: Verdict::NoBaseline,
            };
            if let Some(b) = base_m {
                if base_fp.is_none() || base_fp != cur_fp {
                    row.verdict = Verdict::PlatformSkip;
                } else {
                    if b.mean != 0.0 {
                        row.delta_pct = Some((m.mean - b.mean) / b.mean.abs() * 100.0);
                    }
                    row.verdict = match welch_t_test(&b.summary(), &m.summary()) {
                        Ok(w) => {
                            row.t = Some(w.t);
                            let delta = row.delta_pct.unwrap_or(0.0);
                            let worse = if m.higher_is_better {
                                m.mean < b.mean
                            } else {
                                m.mean > b.mean
                            };
                            if w.significant && delta.abs() >= min_effect_pct {
                                if worse {
                                    Verdict::Regressed
                                } else {
                                    Verdict::Improved
                                }
                            } else {
                                Verdict::Unchanged
                            }
                        }
                        // zero variance on both sides means the metric is
                        // deterministic (artifact sizes, exact counts): an
                        // exact reproduction is unchanged, and an exact
                        // shift is a real effect that needs no t statistic
                        // — only the effect floor applies. This is what
                        // lets size metrics like artifact/bytes_per_weight
                        // participate in the gate.
                        Err(StatError::ZeroVariance) => {
                            let delta = row
                                .delta_pct
                                .unwrap_or(if m.mean == b.mean { 0.0 } else { f64::INFINITY });
                            let worse = if m.higher_is_better {
                                m.mean < b.mean
                            } else {
                                m.mean > b.mean
                            };
                            if delta.abs() < min_effect_pct {
                                Verdict::Unchanged
                            } else if worse {
                                Verdict::Regressed
                            } else {
                                Verdict::Improved
                            }
                        }
                        Err(e) => Verdict::Insufficient(e),
                    };
                }
            }
            rows.push(row);
        }
    }
    Comparison { rows, advisory: baseline.advisory, min_effect_pct }
}

impl Comparison {
    /// `true` when a non-advisory baseline shows a statistically
    /// significant regression on a gated (hot-path) metric — the
    /// condition under which `pvqnet bench-compare` exits nonzero.
    pub fn gate_failed(&self) -> bool {
        !self.advisory && self.gated_regressions() > 0
    }

    /// Gated rows whose verdict is `Regressed`.
    pub fn gated_regressions(&self) -> usize {
        self.rows.iter().filter(|r| r.gate && r.verdict == Verdict::Regressed).count()
    }

    fn count(&self, v: Verdict) -> usize {
        self.rows.iter().filter(|r| r.verdict == v).count()
    }

    /// Render the verdict table plus the summary and gate lines.
    pub fn render(&self) -> String {
        let mut out = format!(
            "bench-compare: {} metric(s), min effect {:.1}%, two-sided Welch 95%\n",
            self.rows.len(),
            self.min_effect_pct
        );
        if self.advisory {
            out.push_str(
                "  baseline is ADVISORY (no recorded reference) — verdicts are informational, \
                 the gate is disarmed\n",
            );
        }
        out.push_str(&format!(
            "  {:<10} {:<30} {:<4} {:>16} {:>16} {:>8} {:>8}  {}\n",
            "experiment", "metric", "gate", "baseline", "current", "Δ%", "t", "verdict"
        ));
        for r in &self.rows {
            let base_cell = match r.base {
                Some((m, c)) => format!("{m:.1} ±{c:.1}"),
                None => "-".to_string(),
            };
            let cur_cell = format!("{:.1} ±{:.1}", r.cur.0, r.cur.1);
            let delta_cell =
                r.delta_pct.map(|d| format!("{d:+.1}%")).unwrap_or_else(|| "-".to_string());
            let t_cell = r.t.map(|t| format!("{t:.2}")).unwrap_or_else(|| "-".to_string());
            out.push_str(&format!(
                "  {:<10} {:<30} {:<4} {:>16} {:>16} {:>8} {:>8}  {}\n",
                r.experiment,
                r.name,
                if r.gate { "yes" } else { "-" },
                base_cell,
                cur_cell,
                delta_cell,
                t_cell,
                r.verdict.label()
            ));
        }
        let insufficient = self
            .rows
            .iter()
            .filter(|r| matches!(r.verdict, Verdict::Insufficient(_)))
            .count();
        out.push_str(&format!(
            "  improved {} · unchanged {} · regressed {} · platform-skip {} · \
             insufficient {} · new {}\n",
            self.count(Verdict::Improved),
            self.count(Verdict::Unchanged),
            self.count(Verdict::Regressed),
            self.count(Verdict::PlatformSkip),
            insufficient,
            self.count(Verdict::NoBaseline),
        ));
        if self.gate_failed() {
            out.push_str(&format!(
                "  GATE: FAIL — {} gated hot-path metric(s) statistically regressed\n",
                self.gated_regressions()
            ));
        } else {
            out.push_str("  GATE: ok\n");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metric(name: &str, mean: f64, std: f64, n: u64, hib: bool, gate: bool) -> Metric {
        Metric {
            experiment: "x".into(),
            name: name.into(),
            unit: "u".into(),
            higher_is_better: hib,
            gate,
            mean,
            ci95: 1.0,
            std,
            iterations: n,
            warmup: 3,
        }
    }

    fn doc(metrics: Vec<Metric>) -> BenchDoc {
        BenchDoc {
            experiment: None,
            advisory: false,
            note: None,
            platform: Some(Platform::capture()),
            metrics,
        }
    }

    #[test]
    fn doc_json_roundtrip() {
        let d = doc(vec![metric("a/b", 123.5, 4.25, 20, true, true)]);
        let back = BenchDoc::parse(&d.to_json_string()).unwrap();
        assert_eq!(back, d);
        // files round-trip too
        let path = std::env::temp_dir().join("pvqnet_benchdoc_test.json");
        d.save(&path).unwrap();
        assert_eq!(BenchDoc::load(&path).unwrap(), d);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn gate_fires_only_on_gated_significant_regressions() {
        let base = doc(vec![
            metric("tput", 1000.0, 10.0, 20, true, true),
            metric("aux", 1000.0, 10.0, 20, true, false),
        ]);
        // both drop 20% — clearly significant — but only `tput` gates
        let cur = doc(vec![
            metric("tput", 800.0, 10.0, 20, true, true),
            metric("aux", 800.0, 10.0, 20, true, false),
        ]);
        let cmp = compare(&base, &[cur.clone()], 5.0);
        assert_eq!(cmp.rows[0].verdict, Verdict::Regressed);
        assert_eq!(cmp.rows[1].verdict, Verdict::Regressed);
        assert_eq!(cmp.gated_regressions(), 1);
        assert!(cmp.gate_failed());
        // an advisory baseline disarms the gate but keeps the verdicts
        let mut advisory = base.clone();
        advisory.advisory = true;
        let cmp = compare(&advisory, &[cur], 5.0);
        assert_eq!(cmp.rows[0].verdict, Verdict::Regressed);
        assert!(!cmp.gate_failed());
        assert!(cmp.render().contains("ADVISORY"));
    }

    #[test]
    fn direction_and_effect_floor() {
        let base = doc(vec![metric("p99", 800.0, 10.0, 20, false, true)]);
        // latency *down* 12.5% is an improvement for lower-is-better
        let cur = doc(vec![metric("p99", 700.0, 10.0, 20, false, true)]);
        let cmp = compare(&base, &[cur], 5.0);
        assert_eq!(cmp.rows[0].verdict, Verdict::Improved);
        assert!(!cmp.gate_failed());
        // a significant-but-tiny shift stays Unchanged under the floor
        let cur = doc(vec![metric("p99", 808.0, 0.5, 20, false, true)]);
        let cmp = compare(&base, &[cur], 5.0);
        assert_eq!(cmp.rows[0].verdict, Verdict::Unchanged);
    }

    #[test]
    fn degenerate_and_unknown_metrics_never_gate() {
        let base = doc(vec![
            metric("one_shot", 100.0, 0.0, 1, true, true),
            metric("exact", 42.0, 0.0, 20, true, true),
        ]);
        let cur = doc(vec![
            metric("one_shot", 50.0, 0.0, 1, true, true),
            metric("exact", 42.0, 0.0, 20, true, true),
            metric("brand_new", 7.0, 0.1, 20, true, true),
        ]);
        let cmp = compare(&base, &[cur], 5.0);
        assert_eq!(cmp.rows[0].verdict, Verdict::Insufficient(StatError::TooFewSamples));
        assert_eq!(cmp.rows[1].verdict, Verdict::Unchanged, "exact reproduction is unchanged");
        assert_eq!(cmp.rows[2].verdict, Verdict::NoBaseline);
        assert!(!cmp.gate_failed());
    }

    #[test]
    fn deterministic_metrics_gate_on_exact_shifts() {
        // zero variance on both sides = deterministic metric: an exact
        // mean shift past the floor is Regressed/Improved (no t-test),
        // so size metrics like artifact/bytes_per_weight really gate
        let base = doc(vec![metric("bytes_per_weight", 0.40, 0.0, 4, false, true)]);
        // +25%: the compressed artifact got fatter — gate fails
        let cur = doc(vec![metric("bytes_per_weight", 0.50, 0.0, 4, false, true)]);
        let cmp = compare(&base, &[cur], 5.0);
        assert_eq!(cmp.rows[0].verdict, Verdict::Regressed);
        assert!(cmp.gate_failed());
        // −25%: smaller is an improvement for lower-is-better
        let cur = doc(vec![metric("bytes_per_weight", 0.30, 0.0, 4, false, true)]);
        let cmp = compare(&base, &[cur], 5.0);
        assert_eq!(cmp.rows[0].verdict, Verdict::Improved);
        assert!(!cmp.gate_failed());
        // a shift under the effect floor stays Unchanged
        let cur = doc(vec![metric("bytes_per_weight", 0.404, 0.0, 4, false, true)]);
        let cmp = compare(&base, &[cur], 5.0);
        assert_eq!(cmp.rows[0].verdict, Verdict::Unchanged);
        // baseline mean zero (delta undefined) still flags a shift
        let base = doc(vec![metric("fallback_layers", 0.0, 0.0, 4, false, true)]);
        let cur = doc(vec![metric("fallback_layers", 2.0, 0.0, 4, false, true)]);
        let cmp = compare(&base, &[cur], 5.0);
        assert_eq!(cmp.rows[0].verdict, Verdict::Regressed);
    }

    #[test]
    fn platform_mismatch_skips() {
        let base = doc(vec![metric("tput", 1000.0, 10.0, 20, true, true)]);
        let mut cur = doc(vec![metric("tput", 500.0, 10.0, 20, true, true)]);
        if let Some(p) = cur.platform.as_mut() {
            p.arch = "wasm32".into();
        }
        let cmp = compare(&base, &[cur], 5.0);
        assert_eq!(cmp.rows[0].verdict, Verdict::PlatformSkip);
        assert!(!cmp.gate_failed());
    }
}
