//! # pvqnet — Pyramid Vector Quantization for Deep Learning
//!
//! Full-system reproduction of V. Liguori, *"Pyramid Vector Quantization
//! for Deep Learning"* (2017): PVQ weight quantization, integer & binary
//! PVQ inference engines, weight compression codecs, hardware cycle
//! simulators, and a batching inference coordinator that serves both
//! AOT-compiled XLA graphs (via PJRT) and the pure-integer PVQ engines.
//!
//! See `DESIGN.md` for the module inventory and the paper-experiment index,
//! and `examples/quickstart.rs` for a five-minute tour.

pub mod artifact;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod hw;
pub mod nn;
pub mod pvq;
pub mod quant;
pub mod runtime;
pub mod testkit;
