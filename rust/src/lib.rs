//! # pvqnet — Pyramid Vector Quantization for Deep Learning
//!
//! Full-system reproduction of V. Liguori, *"Pyramid Vector Quantization
//! for Deep Learning"* (2017): PVQ weight quantization, integer & binary
//! PVQ inference engines with batch-fused serving kernels
//! ([`nn::batch`]) sharded across worker threads ([`nn::parallel`],
//! SIMD-width inner loops in [`nn::simd`]), weight compression codecs,
//! hardware cycle simulators, and a batching inference coordinator that
//! serves both AOT-compiled XLA graphs (via PJRT) and the pure-integer
//! PVQ engines — fronted by a dependency-free, admission-controlled
//! HTTP/1.1 server ([`coordinator::http`]) speaking hand-rolled JSON
//! and Prometheus text ([`coordinator::net`], [`coordinator::metrics`]),
//! and machine-checked under adversarial load by a seeded
//! load-generation + fault-injection harness with a bitwise
//! correctness oracle ([`loadgen`], `pvqnet loadtest`). End-to-end
//! request tracing ([`obs`]) records per-stage spans into lock-free
//! ring buffers and exports Chrome trace-event JSON (`GET /v1/trace`).
//! Performance is tracked by a measured bench protocol with committed
//! baselines and a statistical regression gate ([`bench`],
//! `pvqnet bench-compare`).
//!
//! See `docs/ARCHITECTURE.md` for the module inventory, data-flow
//! diagram, and the paper-experiment index; `docs/PVQM_FORMAT.md` for
//! the normative `.pvqm` container spec; and `examples/quickstart.rs`
//! for a five-minute tour.
#![warn(missing_docs)]
#![deny(rustdoc::broken_intra_doc_links)]

pub mod artifact;
pub mod bench;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod hw;
pub mod loadgen;
pub mod nn;
pub mod obs;
pub mod pvq;
pub mod quant;
pub mod runtime;
pub mod testkit;
