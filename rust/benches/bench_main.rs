//! Benchmark & reproduction harness (criterion is unavailable offline —
//! this is a self-contained harness on the measured protocol in
//! `pvqnet::bench`: fixed warmup + K timed iterations, outlier-aware
//! `mean ± ci95` summaries, platform-stamped JSON).
//!
//!     cargo bench                       # run everything
//!     cargo bench -- table5             # run one experiment
//!     cargo bench -- --list             # list experiments
//!     cargo bench -- batch shard http artifact --smoke  # CI smoke: 1 iteration each
//!     cargo bench -- batch shard http loadgen artifact --baseline-out candidate.json
//!
//! One target per paper table/figure (docs/ARCHITECTURE.md §4) plus
//! microbenchmarks and ablations. Experiments that need trained
//! artifacts print SKIP when `make artifacts` has not been run.
//! `--smoke` swaps the measured protocol for a single untimed-warmup
//! iteration so CI can execute the kernel benches (and still emit their
//! `BENCH_*.json`, with `iterations: 1` marking the numbers as
//! statistically void) without paying for stable timings. Every metric
//! recorded by the JSON-emitting experiments (batch, shard, binary,
//! http, loadgen, trace, artifact) also lands in the merged
//! `--baseline-out` document, which `pvqnet bench-compare` consumes.

use pvqnet::bench::{fmt_secs as fmt_t, BenchDoc, Measurement, Metric, Platform, Protocol};
use pvqnet::compress::codec_survey;
use pvqnet::coordinator::{Engine, Server, ServerConfig};
use pvqnet::data::Dataset;
use pvqnet::hw::{add_only_arch, bin_accum_arch, bin_counter_arch, mult_arch, HwReport, LutRow};
use pvqnet::nn::weights::load_model;
use pvqnet::nn::{ModelSpec, Tensor};
use pvqnet::pvq::{
    encode_fast, encode_grouped, encode_grouped_shared_rho, encode_opt,
    reconstruction_mse, RhoMode,
};
use pvqnet::quant::{distribution_table, evaluate, quantize};
use pvqnet::testkit::Rng;
use std::path::Path;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

// ------------------------------------------------------------------ harness

/// `--smoke`: run every measured closure exactly once (CI bit-rot gate —
/// the numbers are meaningless, the code paths and JSON outputs are not).
static SMOKE: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

fn smoke() -> bool {
    SMOKE.load(std::sync::atomic::Ordering::Relaxed)
}

/// Microbenchmark protocol for this invocation (single-shot under
/// `--smoke`).
fn proto() -> Protocol {
    if smoke() {
        Protocol::SMOKE
    } else {
        Protocol::MICRO
    }
}

/// Macro-experiment protocol (whole sweeps / load runs per iteration).
fn proto_macro() -> Protocol {
    if smoke() {
        Protocol::SMOKE
    } else {
        Protocol::MACRO
    }
}

/// Platform captured once per invocation; stamped into every JSON doc.
fn platform() -> Platform {
    static PLATFORM: OnceLock<Platform> = OnceLock::new();
    PLATFORM.get_or_init(Platform::capture).clone()
}

/// Metrics recorded by the JSON experiments this invocation (also the
/// source for the merged `--baseline-out` document).
static RECORDED: Mutex<Vec<Metric>> = Mutex::new(Vec::new());

/// Record one measured metric under `experiment`.
fn record(experiment: &str, name: &str, unit: &str, hib: bool, gate: bool, m: &Measurement) {
    RECORDED.lock().unwrap().push(Metric {
        experiment: experiment.to_string(),
        name: name.to_string(),
        unit: unit.to_string(),
        higher_is_better: hib,
        gate,
        mean: m.mean(),
        ci95: m.ci95(),
        std: m.summary.std,
        iterations: m.n(),
        warmup: m.warmup as u64,
    });
}

/// Record a deterministic single-shot scalar (bits/weight and friends):
/// `iterations: 1`, never gated — the comparison layer reports these as
/// "insufficient" rather than pretending significance.
fn record_scalar(experiment: &str, name: &str, unit: &str, hib: bool, value: f64) {
    RECORDED.lock().unwrap().push(Metric {
        experiment: experiment.to_string(),
        name: name.to_string(),
        unit: unit.to_string(),
        higher_is_better: hib,
        gate: false,
        mean: value,
        ci95: 0.0,
        std: 0.0,
        iterations: 1,
        warmup: 0,
    });
}

/// Write `BENCH_<experiment>.json` from the metrics recorded so far
/// under that experiment name.
fn write_doc(experiment: &str) {
    let metrics: Vec<Metric> = RECORDED
        .lock()
        .unwrap()
        .iter()
        .filter(|m| m.experiment == experiment)
        .cloned()
        .collect();
    let doc = BenchDoc {
        experiment: Some(experiment.to_string()),
        advisory: false,
        note: None,
        platform: Some(platform()),
        metrics,
    };
    let path = format!("BENCH_{experiment}.json");
    doc.save(Path::new(&path)).unwrap();
    println!("  wrote {path}");
}

/// Time a closure under the current protocol and print `mean ± ci`.
fn time_it<F: FnMut()>(name: &str, f: F) {
    let m = proto().measure(f);
    println!("  {name:<44} {}", m.format_time());
}

/// Samples/second of `f` (which processes `samples_per_call`) under the
/// current protocol.
fn throughput<F: FnMut()>(samples_per_call: usize, f: F) -> Measurement {
    proto().measure_rate(samples_per_call as f64, f)
}

fn have_artifacts() -> bool {
    Path::new("artifacts/manifest.txt").exists()
}

fn load_net(net: &str) -> Option<(pvqnet::nn::Model, Dataset)> {
    if !have_artifacts() {
        println!("  SKIP (run `make artifacts`)");
        return None;
    }
    let spec = ModelSpec::by_name(net).unwrap();
    let model = load_model(Path::new(&format!("artifacts/net_{net}.pvqw")), &spec).ok()?;
    let data = if spec.input_shape == vec![784] {
        Dataset::load(Path::new("artifacts/mnist_test.bin")).ok()?
    } else {
        Dataset::load(Path::new("artifacts/cifar_test.bin")).ok()?
    };
    Some((model, data))
}

// ------------------------------------------------------------- experiments

/// Tables 1–4: anatomy + the ratios used.
fn bench_tables(net: &str) {
    let spec = ModelSpec::by_name(net).unwrap();
    println!("{}", spec.anatomy_table(&spec.paper_ratios()));
}

/// §VII accuracy rows (paper: A 98.27→95.33, B 78.46→73.21,
/// C 94.14→91.28, D 61.62→58.54 — absolute numbers are testbed-specific;
/// the *shape* is the claim).
fn bench_acc(net: &str) {
    let Some((model, data)) = load_net(net) else { return };
    let limit = if model.spec.input_shape.len() == 3 { 200 } else { 500 };
    let q = quantize(&model, &model.spec.paper_ratios(), RhoMode::Norm).unwrap();
    let rep = evaluate(&model, &q, &data, limit).unwrap();
    println!("{}", rep.render());
}

/// Tables 5–8: weight distributions after PVQ.
fn bench_dist(net: &str) {
    let Some((model, _)) = load_net(net) else { return };
    let q = quantize(&model, &model.spec.paper_ratios(), RhoMode::Norm).unwrap();
    println!("{}", distribution_table(&q));
}

/// §VI: bits/weight for every codec on every layer of nets A and B.
fn bench_golomb() {
    for net in ["a", "b"] {
        let Some((model, _)) = load_net(net) else { return };
        let q = quantize(&model, &model.spec.paper_ratios(), RhoMode::Norm).unwrap();
        println!("net {}:", net.to_uppercase());
        for (r, &li) in q.reports.iter().zip(&model.spec.weighted_layers()) {
            let layer = q.quant_model.layers[li].as_ref().unwrap();
            let mut comps = layer.w.clone();
            comps.extend_from_slice(&layer.b_pyramid);
            let pv = pvqnet::pvq::PvqVector { k: layer.k, components: comps, rho: layer.rho };
            let survey = codec_survey(&pv);
            let eg = survey.iter().find(|(n, _)| n == "exp-golomb").unwrap().1;
            let rle = survey.iter().find(|(n, _)| n == "rle").unwrap().1;
            let hf = survey.iter().find(|(n, _)| n == "huffman(V=7)").unwrap().1;
            let ent = survey.iter().find(|(n, _)| n == "entropy-bound").unwrap().1;
            println!(
                "  {:<7} N/K {:>5.2}  EG {:>6.3}  RLE {:>6.3}  Huff {:>6.3}  H₀ {:>6.3} bits/w",
                r.label, r.ratio, eg, rle, hf, ent
            );
        }
    }
    println!("(paper §VI reference points: FC0-A ≈1.4 b/w, CONV1-B ≈2.8 b/w)");
}

/// Fig. 1: serial dot-product circuits, cycles + wall time.
fn bench_fig1() {
    let mut rng = Rng::new(1);
    let n = 4096;
    let v = rng.laplacian_vec(n, 1.0);
    let x: Vec<i64> = (0..n).map(|_| rng.below(256) as i64).collect();
    for ratio in [1usize, 2, 5] {
        let q = encode_fast(&v, (n / ratio) as u32, RhoMode::Norm);
        let m = mult_arch(&q.components, &x);
        let a = add_only_arch(&q.components, &x);
        println!(
            "  N={n} N/K={ratio}: mult-arch {} cycles, add-only {} cycles (K={}), nonzeros {}",
            m.cycles,
            a.cycles,
            q.k,
            q.nonzeros()
        );
        assert_eq!(m.value, a.value);
        let (qc, xc) = (q.components.clone(), x.clone());
        time_it(&format!("fig1 mult-arch sim (N={n}, N/K={ratio})"), || {
            std::hint::black_box(mult_arch(&qc, &xc));
        });
        let (qc, xc) = (q.components.clone(), x.clone());
        time_it(&format!("fig1 add-only sim  (N={n}, N/K={ratio})"), || {
            std::hint::black_box(add_only_arch(&qc, &xc));
        });
    }
}

/// Fig. 2: binary circuits.
fn bench_fig2() {
    let mut rng = Rng::new(2);
    let n = 4096;
    let v = rng.laplacian_vec(n, 1.0);
    let xb: Vec<i8> = (0..n).map(|_| if rng.next_u64() & 1 == 1 { 1 } else { -1 }).collect();
    for ratio in [1usize, 5] {
        let q = encode_fast(&v, (n / ratio) as u32, RhoMode::Norm);
        let acc = bin_accum_arch(&q.components, &xb);
        let cnt = bin_counter_arch(&q.components, &xb);
        assert_eq!(acc.value, cnt.value);
        println!(
            "  N={n} N/K={ratio}: accum {} cycles (≤K), counter {} cycles (=K={})",
            acc.cycles, cnt.cycles, q.k
        );
    }
}

/// Fig. 3: LUT packing resources.
fn bench_fig3() {
    let mut rng = Rng::new(3);
    for (n, ratio) in [(512usize, 1usize), (512, 5), (4096, 5)] {
        let v = rng.laplacian_vec(n, 1.0);
        let q = encode_fast(&v, (n / ratio) as u32, RhoMode::Norm);
        let row = LutRow::compile(&q.components, 0);
        let cost = row.cost();
        println!(
            "  N={n} N/K={ratio}: {} six-input LUT groups × {} bits, {} tree adds",
            cost.lut_groups, cost.bits, cost.tree_adds
        );
        let xb: Vec<i8> = (0..n).map(|_| if rng.next_u64() & 1 == 1 { 1 } else { -1 }).collect();
        time_it(&format!("fig3 LUT row eval (N={n}, N/K={ratio})"), || {
            std::hint::black_box(row.eval(&xb));
        });
    }
}

/// §III op-count claim + §VIII totals on a real net.
fn bench_opcount() {
    let Some((model, data)) = load_net("a") else { return };
    let q = quantize(&model, &model.spec.paper_ratios(), RhoMode::Norm).unwrap();
    let rep = evaluate(&model, &q, &data, 50).unwrap();
    println!(
        "  per-sample: float {} MACs → PVQ {} adds + {} mults (add-only arch: {} adds)",
        rep.ops.float_macs, rep.ops.adds, rep.ops.mults, rep.ops.adds_addonly
    );
    println!("{}", HwReport::from_model(&q.quant_model).render());
}

/// Ablation: ρ = r/‖ŷ‖₂ (paper) vs least-squares ρ.
fn bench_ablation_rho() {
    let mut rng = Rng::new(4);
    for ratio in [1usize, 2, 5] {
        let mut err_norm = 0.0;
        let mut err_lsq = 0.0;
        let trials = 30;
        for _ in 0..trials {
            let n = 2048;
            let v = rng.laplacian_vec(n, 1.0);
            let k = (n / ratio) as u32;
            err_norm += reconstruction_mse(&v, &encode_fast(&v, k, RhoMode::Norm));
            err_lsq += reconstruction_mse(&v, &encode_fast(&v, k, RhoMode::Lsq));
        }
        println!(
            "  N/K={ratio}: MSE norm-ρ {:.6}  lsq-ρ {:.6}  (lsq {:.2}% better)",
            err_norm / trials as f64,
            err_lsq / trials as f64,
            100.0 * (1.0 - err_lsq / err_norm)
        );
    }
}

/// Ablation §V: grouped (own ρ each) vs whole-layer shared-ρ encoding.
fn bench_ablation_group() {
    let mut rng = Rng::new(5);
    let n = 4096;
    let v = rng.laplacian_vec(n, 1.0);
    for group in [64usize, 256, 1024] {
        let k_per = (group / 2) as u32;
        let gi = encode_grouped(&v, group, k_per, RhoMode::Lsq);
        let total_k = gi.total_k() as u32;
        let gs = encode_grouped_shared_rho(&v, group, total_k, RhoMode::Lsq);
        let mi: f64 = v.iter().zip(gi.decode()).map(|(a, b)| (a - b) * (a - b)).sum::<f64>() / n as f64;
        let ms: f64 = v.iter().zip(gs.decode()).map(|(a, b)| (a - b) * (a - b)).sum::<f64>() / n as f64;
        println!(
            "  group={group:>5} K_total={total_k}: grouped-ρ MSE {mi:.6} ({} gains) vs shared-ρ {ms:.6} (1 gain)",
            gi.groups.len()
        );
    }
}

/// Encoder throughput: layer-scale O(N log N) vs greedy O(NK).
fn bench_encode() {
    let mut rng = Rng::new(6);
    for n in [4096usize, 65_536, 401_920] {
        let v = rng.laplacian_vec(n, 1.0);
        let k = (n / 5) as u32;
        let vc = v.clone();
        time_it(&format!("encode_fast N={n} K=N/5"), || {
            std::hint::black_box(encode_fast(&vc, k, RhoMode::Norm));
        });
    }
    let v = rng.laplacian_vec(1024, 1.0);
    time_it("encode_opt  N=1024 K=N/5 (O(NK))", || {
        std::hint::black_box(encode_opt(&v, 204, RhoMode::Norm));
    });
}

/// Integer PVQ engine vs float engine per-sample latency (net A).
fn bench_engines() {
    let Some((model, data)) = load_net("a") else { return };
    let q = quantize(&model, &model.spec.paper_ratios(), RhoMode::Norm).unwrap();
    let x = data.sample_f32(0, true);
    time_it("float engine forward (net A)", || {
        std::hint::black_box(pvqnet::nn::forward(&model, &x));
    });
    let xq = data.sample_f32(0, true);
    time_it("quantized-float engine forward (net A)", || {
        std::hint::black_box(pvqnet::nn::forward(&q.float_model, &xq));
    });
    let xi = data.sample_i64(0, true);
    time_it("integer PVQ engine forward (net A)", || {
        std::hint::black_box(pvqnet::nn::forward_int(&q.quant_model, &xi).unwrap());
    });
    let compiled = pvqnet::nn::CompiledQuantModel::compile(&q.quant_model).unwrap();
    let xi2 = data.sample_i64(0, true);
    time_it("CSR-compiled PVQ engine forward (net A)", || {
        std::hint::black_box(compiled.forward(&xi2));
    });
}

/// Coordinator throughput: batched serving, PVQ engine (net A).
fn bench_serve() {
    let Some((model, data)) = load_net("a") else { return };
    let q = quantize(&model, &model.spec.paper_ratios(), RhoMode::Norm).unwrap();
    let compiled =
        Arc::new(pvqnet::nn::CompiledQuantModel::compile(&q.quant_model).unwrap());
    let shape = model.spec.input_shape.clone();
    for max_batch in [1usize, 8, 32] {
        let server = Server::start(
            Engine::PvqCompiled(compiled.clone(), shape.clone()),
            ServerConfig {
                max_batch,
                max_wait: Duration::from_micros(500),
                workers: 1,
                queue_cap: 8192,
                shards: 1,
            },
        );
        let n = 300;
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(n);
        for i in 0..n {
            rxs.push(server.submit(data.sample(i % data.n).to_vec()).unwrap());
        }
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let dt = t0.elapsed();
        println!(
            "  max_batch={max_batch:>3}: {:>8.0} req/s  [{}]",
            n as f64 / dt.as_secs_f64(),
            server.metrics().summary()
        );
        server.shutdown();
    }
}

/// HTTP front-end latency sweep: concurrent keep-alive loopback clients
/// hammer `POST /v1/classify` (synth net A through the registry's auto
/// engine) at client counts {1, 4, 16}. Each protocol iteration is one
/// full wave (clients × per-client requests, fixed seeds); the
/// per-iteration p50/p99/req/s samples condense into `mean ± ci`
/// metrics in `BENCH_http.json` — `p99_us` is a gated hot path. A
/// second, connection-scaling sweep holds {64, 512, 2048} keep-alive
/// connections open simultaneously against the epoll loops (bounded
/// driver threads, one request per connection per wave) and records the
/// `conns{N}/p99_us` (gated) and `conns{N}/rps` families. Under
/// `--smoke` each sweep runs a single cheap wave (CI bit-rot gate).
fn bench_http() {
    use pvqnet::coordinator::{EngineKind, HttpConfig, HttpServer, ModelRegistry};
    use pvqnet::testkit::http::HttpTestClient;

    let spec = ModelSpec::by_name("a").unwrap();
    let model = pvqnet::nn::Model::synth(&spec, 42);
    let q = quantize(&model, &spec.paper_ratios(), RhoMode::Norm).unwrap();
    let mut reg = ModelRegistry::new(ServerConfig { queue_cap: 8192, ..Default::default() });
    reg.register_quant("net_a", q.quant_model, EngineKind::Auto, None).unwrap();
    // the epoll front end multiplexes every client; the default budgets
    // (4096 connections) cover both sweeps below
    let http_cfg = HttpConfig::default();
    let server = HttpServer::start(reg, http_cfg, "127.0.0.1:0").unwrap();
    let addr = server.addr();
    let input_len: usize = spec.input_shape.iter().product();

    let p = proto_macro();
    for clients in [1usize, 4, 16] {
        let per_client = if smoke() { 1 } else { 50 };
        // one wave = the full client sweep; returns (p50µs, p99µs, req/s)
        let run_wave = || -> (f64, f64, f64) {
            let t0 = Instant::now();
            let mut handles = Vec::new();
            for ci in 0..clients {
                handles.push(std::thread::spawn(move || {
                    let mut rng = Rng::new(900 + ci as u64);
                    let mut client = HttpTestClient::connect(addr).unwrap();
                    let mut lat_us = Vec::with_capacity(per_client);
                    for _ in 0..per_client {
                        let pixels: Vec<String> =
                            (0..input_len).map(|_| rng.below(256).to_string()).collect();
                        let body = format!("{{\"pixels\":[{}]}}", pixels.join(","));
                        let t = Instant::now();
                        let resp = client.post_classify(&body, true);
                        assert_eq!(resp.status, 200, "{}", resp.body);
                        lat_us.push(t.elapsed().as_secs_f64() * 1e6);
                    }
                    lat_us
                }));
            }
            let mut lats: Vec<f64> =
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
            let wall = t0.elapsed().as_secs_f64();
            lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let n = lats.len();
            (lats[n / 2], lats[(n * 99 / 100).min(n - 1)], n as f64 / wall.max(1e-12))
        };
        for _ in 0..p.warmup {
            run_wave();
        }
        let (mut p50s, mut p99s, mut rpss) = (Vec::new(), Vec::new(), Vec::new());
        for _ in 0..p.iters.max(1) {
            let (p50, p99, rps) = run_wave();
            p50s.push(p50);
            p99s.push(p99);
            rpss.push(rps);
        }
        let m50 = Measurement::from_values(p50s, p.warmup);
        let m99 = Measurement::from_values(p99s, p.warmup);
        let mrps = Measurement::from_values(rpss, p.warmup);
        println!(
            "  clients={clients:>3}: {}  p50 {:>8.0} ±{:.0}µs  p99 {:>8.0} ±{:.0}µs  \
             ({} requests/wave)",
            mrps.format_rate("req/s"),
            m50.mean(),
            m50.ci95(),
            m99.mean(),
            m99.ci95(),
            clients * per_client
        );
        record("http", &format!("c{clients}/p50_us"), "us", false, false, &m50);
        record("http", &format!("c{clients}/p99_us"), "us", false, true, &m99);
        record("http", &format!("c{clients}/rps"), "req/s", true, false, &mrps);
    }

    // connection-scaling sweep: N keep-alive connections all open at
    // once against the event loops. A bounded driver-thread pool owns
    // the sockets (connections ÷ threads apiece) and sends one request
    // per connection per wave, so the in-flight request count stays
    // small while the *open-socket* count — the thing the epoll front
    // end claims to scale in — is exactly N.
    for conns in [64usize, 512, 2048] {
        let threads = conns.min(8);
        let lat_bucket: Mutex<Vec<f64>> = Mutex::new(Vec::new());
        let start = std::sync::Barrier::new(threads + 1);
        let done = std::sync::Barrier::new(threads + 1);
        let stop = std::sync::atomic::AtomicBool::new(false);
        let waves = p.warmup + p.iters.max(1);
        let (mut p99s, mut rpss) = (Vec::new(), Vec::new());
        std::thread::scope(|s| {
            for t in 0..threads {
                let lat_bucket = &lat_bucket;
                let (start, done, stop) = (&start, &done, &stop);
                s.spawn(move || {
                    let mut rng = Rng::new(3000 + t as u64);
                    let n_conns = conns / threads + usize::from(t < conns % threads);
                    let mut clients: Vec<HttpTestClient> = (0..n_conns)
                        .map(|_| HttpTestClient::connect(addr).unwrap())
                        .collect();
                    loop {
                        start.wait();
                        if stop.load(std::sync::atomic::Ordering::SeqCst) {
                            break;
                        }
                        let mut lats = Vec::with_capacity(clients.len());
                        for c in clients.iter_mut() {
                            let pixels: Vec<String> = (0..input_len)
                                .map(|_| rng.below(256).to_string())
                                .collect();
                            let body = format!("{{\"pixels\":[{}]}}", pixels.join(","));
                            let t0 = Instant::now();
                            let resp = c.post_classify(&body, true);
                            assert_eq!(resp.status, 200, "{}", resp.body);
                            lats.push(t0.elapsed().as_secs_f64() * 1e6);
                        }
                        lat_bucket.lock().unwrap().extend(lats);
                        done.wait();
                    }
                });
            }
            for w in 0..waves {
                let t0 = Instant::now();
                start.wait();
                done.wait();
                let wall = t0.elapsed().as_secs_f64();
                let mut lats = std::mem::take(&mut *lat_bucket.lock().unwrap());
                if w < p.warmup {
                    continue;
                }
                lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let n = lats.len();
                p99s.push(lats[(n * 99 / 100).min(n - 1)]);
                rpss.push(n as f64 / wall.max(1e-12));
            }
            stop.store(true, std::sync::atomic::Ordering::SeqCst);
            start.wait();
        });
        let m99 = Measurement::from_values(p99s, p.warmup);
        let mrps = Measurement::from_values(rpss, p.warmup);
        println!(
            "  conns={conns:>5}: {}  p99 {:>8.0} ±{:.0}µs  (1 req/conn/wave, {threads} driver threads)",
            mrps.format_rate("req/s"),
            m99.mean(),
            m99.ci95(),
        );
        record("http", &format!("conns{conns}/p99_us"), "us", false, true, &m99);
        record("http", &format!("conns{conns}/rps"), "req/s", true, false, &mrps);
    }
    write_doc("http");
    println!("  [{}]", server.summary().trim_end().replace('\n', "; "));
    server.shutdown();
}

/// Batched vs scalar inference throughput (B ∈ {1, 4, 16, 64}) for the
/// CSR engine (synth net A) and the binary popcount engine (synth net C):
/// the scalar loop walks the weight structure once per sample, the
/// batch-fused `forward_block` path walks it once per micro-batch. Runs
/// on synthetic weights (no `make artifacts` needed) and emits
/// `BENCH_batch.json`; `batched_sps` is a gated hot path.
fn bench_batch() {
    use pvqnet::nn::batch::ActivationBlock;
    use pvqnet::nn::tensor::ITensor;
    use pvqnet::nn::{BinaryNet, CompiledQuantModel, Model};

    let mut rng = Rng::new(77);
    for (net, engine_name) in [("a", "pvq-csr"), ("c", "binary")] {
        let spec = ModelSpec::by_name(net).unwrap();
        let model = Model::synth(&spec, 42);
        let q = quantize(&model, &spec.paper_ratios(), RhoMode::Norm).unwrap();
        let input_len: usize = spec.input_shape.iter().product();
        let samples: Vec<Vec<u8>> = (0..64)
            .map(|_| (0..input_len).map(|_| rng.below(256) as u8).collect())
            .collect();
        println!("  net {} ({engine_name}):", spec.name);

        let csr = (engine_name == "pvq-csr")
            .then(|| CompiledQuantModel::compile(&q.quant_model).unwrap());
        let bin = (engine_name == "binary")
            .then(|| BinaryNet::compile(&q.quant_model).unwrap());

        let mut scalar_b1 = 0.0f64;
        for b in [1usize, 4, 16, 64] {
            let wave = &samples[..b];
            let views: Vec<&[u8]> = wave.iter().map(|s| s.as_slice()).collect();
            let (scalar_sps, batched_sps) = match (&csr, &bin) {
                (Some(m), _) => {
                    let tensors: Vec<ITensor> = wave
                        .iter()
                        .map(|s| ITensor::from_u8(&spec.input_shape, s))
                        .collect();
                    let block = ActivationBlock::from_samples_u8(&views).unwrap();
                    (
                        throughput(b, || {
                            for t in &tensors {
                                std::hint::black_box(m.forward(t));
                            }
                        }),
                        throughput(b, || {
                            std::hint::black_box(m.forward_block(&block).unwrap());
                        }),
                    )
                }
                (_, Some(m)) => (
                    throughput(b, || {
                        for s in &views {
                            std::hint::black_box(m.forward_u8(s).unwrap());
                        }
                    }),
                    throughput(b, || {
                        std::hint::black_box(m.forward_block_u8(&views).unwrap());
                    }),
                ),
                _ => unreachable!("one engine per net"),
            };
            if b == 1 {
                scalar_b1 = scalar_sps.mean();
            }
            let speedup = batched_sps.mean() / scalar_b1.max(1e-9);
            println!(
                "    B={b:>3}: scalar-loop {}  batched {}  ({speedup:.2}x vs B=1 scalar)",
                scalar_sps.format_rate("samp/s"),
                batched_sps.format_rate("samp/s")
            );
            record(
                "batch",
                &format!("{engine_name}/b{b}/scalar_sps"),
                "samples/s",
                true,
                false,
                &scalar_sps,
            );
            record(
                "batch",
                &format!("{engine_name}/b{b}/batched_sps"),
                "samples/s",
                true,
                true,
                &batched_sps,
            );
        }
    }
    write_doc("batch");
}

/// Sharded vs single-shard `forward_block`: shards ∈ {1, 2, 4, 8} ×
/// B ∈ {16, 64} for the CSR engine (synth net A) and the binary
/// popcount engine (synth net C). The shard planner splits each layer's
/// output rows over scoped worker threads; results stay bitwise
/// identical (tests/batch_equivalence.rs), so this sweep measures pure
/// scaling. Runs on synthetic weights and emits `BENCH_shard.json`;
/// every `sps` point is a gated hot path.
fn bench_shard() {
    use pvqnet::nn::batch::ActivationBlock;
    use pvqnet::nn::{BinaryNet, CompiledQuantModel, Model};

    let mut rng = Rng::new(78);
    println!(
        "  host parallelism: {}",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    for (net, engine_name) in [("a", "pvq-csr"), ("c", "binary")] {
        let spec = ModelSpec::by_name(net).unwrap();
        let model = Model::synth(&spec, 42);
        let q = quantize(&model, &spec.paper_ratios(), RhoMode::Norm).unwrap();
        let input_len: usize = spec.input_shape.iter().product();
        let samples: Vec<Vec<u8>> = (0..64)
            .map(|_| (0..input_len).map(|_| rng.below(256) as u8).collect())
            .collect();
        println!("  net {} ({engine_name}):", spec.name);
        // compile once; set_shards re-plans a compiled model in place
        let mut csr = (engine_name == "pvq-csr")
            .then(|| CompiledQuantModel::compile(&q.quant_model).unwrap());
        let mut bin =
            (engine_name == "binary").then(|| BinaryNet::compile(&q.quant_model).unwrap());
        for b in [16usize, 64] {
            let wave = &samples[..b];
            let views: Vec<&[u8]> = wave.iter().map(|s| s.as_slice()).collect();
            let mut base_sps = 0.0f64;
            for shards in [1usize, 2, 4, 8] {
                let m = if let Some(m) = csr.as_mut() {
                    m.set_shards(shards);
                    let block = ActivationBlock::from_samples_u8(&views).unwrap();
                    let m = &*m;
                    throughput(b, || {
                        std::hint::black_box(m.forward_block(&block).unwrap());
                    })
                } else {
                    let m = bin.as_mut().expect("one engine per net");
                    m.set_shards(shards);
                    let m = &*m;
                    throughput(b, || {
                        std::hint::black_box(m.forward_block_u8(&views).unwrap());
                    })
                };
                if shards == 1 {
                    base_sps = m.mean();
                }
                let speedup = m.mean() / base_sps.max(1e-9);
                println!(
                    "    B={b:>3} shards={shards}: {}  ({speedup:.2}x vs 1 shard)",
                    m.format_rate("samp/s")
                );
                record(
                    "shard",
                    &format!("{engine_name}/b{b}/s{shards}/sps"),
                    "samples/s",
                    true,
                    true,
                    &m,
                );
            }
        }
    }
    write_doc("shard");
}

/// Closed-loop `loadgen` harness runs: seeded traffic + fault schedule
/// against both the HTTP and in-process paths, every success checked by
/// the bitwise oracle, repeated under the macro protocol so the p99s
/// carry confidence intervals; emits `BENCH_loadgen.json` (both p99
/// metrics are gated hot paths). Under `--smoke` a single small run
/// (the CI loadtest job runs the CLI variant with drain-mid-flight on
/// top, which writes the richer `BENCH_load.json` report).
fn bench_loadgen() {
    use pvqnet::loadgen::{run, LoadConfig, TrafficShape};

    let cfg = LoadConfig {
        seed: 42,
        requests: if smoke() { 48 } else { 240 },
        shape: TrafficShape::Closed { clients: 4 },
        fault_every: 6,
        ..Default::default()
    };
    let p = proto_macro();
    let t0 = Instant::now();
    let (mut http_p99, mut inproc_p99, mut http_rps) = (Vec::new(), Vec::new(), Vec::new());
    let mut last = None;
    for i in 0..p.warmup + p.iters.max(1) {
        let report = run(&cfg).expect("loadgen run");
        assert!(report.passed(), "loadgen bench failed its own oracle/accounting gate");
        if i >= p.warmup {
            if let Some(h) = &report.http {
                http_p99.push(h.hist.quantile_us(0.99) as f64);
                http_rps.push(h.throughput_rps());
            }
            if let Some(ip) = &report.inproc {
                inproc_p99.push(ip.hist.quantile_us(0.99) as f64);
            }
        }
        last = Some(report);
    }
    if let Some(report) = &last {
        print!("{}", report.render().replace('\n', "\n  "));
    }
    let m_http = Measurement::from_values(http_p99, p.warmup);
    let m_inproc = Measurement::from_values(inproc_p99, p.warmup);
    let m_rps = Measurement::from_values(http_rps, p.warmup);
    println!(
        "\n  over {} run(s): http p99 {:.0} ±{:.0}µs · inproc p99 {:.0} ±{:.0}µs · \
         http {:.0} ±{:.0} ok-req/s ({} total)",
        m_http.n(),
        m_http.mean(),
        m_http.ci95(),
        m_inproc.mean(),
        m_inproc.ci95(),
        m_rps.mean(),
        m_rps.ci95(),
        fmt_t(t0.elapsed().as_secs_f64())
    );
    record("loadgen", "http/p99_us", "us", false, true, &m_http);
    record("loadgen", "inproc/p99_us", "us", false, true, &m_inproc);
    record("loadgen", "http/rps", "req/s", true, false, &m_rps);
    write_doc("loadgen");
}

/// Tracing overhead: the disabled-path hook cost (the overhead contract
/// — one relaxed atomic load, see docs/ARCHITECTURE.md §Observability)
/// and end-to-end batched classify throughput with tracing off vs on
/// (sampling 1-in-1, every span recorded). Emits `BENCH_trace.json`
/// (informational — not gated).
fn bench_trace() {
    use pvqnet::coordinator::{Classify, ClassifyRequest, EngineKind, ModelRegistry};
    use pvqnet::obs;

    // hook microbench: current_ctx() is the hook the hot path calls on
    // every request/shard; with tracing off it is one relaxed load
    let hook = |label: &str, on: bool| {
        obs::set_enabled(on);
        let m = proto()
            .measure(|| {
                for _ in 0..1000 {
                    std::hint::black_box(obs::current_ctx());
                }
            })
            .scaled(1e9 / 1000.0);
        obs::set_enabled(false);
        println!(
            "  obs hook, tracing {label:<3}: {:>7.2} ±{:.2} ns/call (n={})",
            m.mean(),
            m.ci95(),
            m.n()
        );
        record("trace", &format!("hook_{label}_ns"), "ns/hook", false, false, &m);
    };
    hook("off", false);
    obs::set_sampling(1);
    hook("on", true);

    // end-to-end: batched registry classify waves, tracing off vs on
    // (on = every request sampled, full span chain recorded)
    let spec = ModelSpec::by_name("a").unwrap();
    let model = pvqnet::nn::Model::synth(&spec, 42);
    let input_len: usize = spec.input_shape.iter().product();
    let mut rng = Rng::new(81);
    let wave: Vec<Vec<u8>> = (0..16)
        .map(|_| (0..input_len).map(|_| rng.below(256) as u8).collect())
        .collect();
    for (label, on) in [("off", false), ("on", true)] {
        let q = quantize(&model, &spec.paper_ratios(), RhoMode::Norm).unwrap();
        let mut reg =
            ModelRegistry::new(ServerConfig { queue_cap: 8192, ..Default::default() });
        reg.register_quant("net_a", q.quant_model, EngineKind::Auto, None).unwrap();
        obs::set_enabled(on);
        let m = throughput(wave.len(), || {
            let ctx = obs::request_ctx();
            reg.submit(ClassifyRequest::batch(wave.clone()).with_trace(ctx)).unwrap();
        });
        obs::set_enabled(false);
        reg.shutdown();
        println!("  tracing {label:<3}: {}", m.format_rate("samp/s"));
        record("trace", &format!("e2e_{label}_sps"), "samples/s", true, false, &m);
    }
    write_doc("trace");
}

/// Zero-plane-skipping binary kernels (synth net C): gated end-to-end
/// samples/s for the batch-fused classify path, plus the fraction of
/// bit-plane words the kernels skipped. The skip fraction is a pure
/// function of the compiled masks and the sample block — deterministic,
/// so it is recorded as a zero-variance sample set (bench-compare
/// judges it by exact mean shift) and gates: a drop toward 0 means the
/// occupancy masks stopped eliding work.
fn bench_binary() {
    use pvqnet::nn::{BinaryNet, Model};

    let spec = ModelSpec::by_name("c").unwrap();
    let model = Model::synth(&spec, 42);
    let q = quantize(&model, &spec.paper_ratios(), RhoMode::Norm).unwrap();
    let net = BinaryNet::compile(&q.quant_model).unwrap();
    let input_len: usize = spec.input_shape.iter().product();
    let mut rng = Rng::new(79);
    let b = 64usize;
    let samples: Vec<Vec<u8>> = (0..b)
        .map(|_| (0..input_len).map(|_| rng.below(256) as u8).collect())
        .collect();
    let views: Vec<&[u8]> = samples.iter().map(|s| s.as_slice()).collect();

    let sps = throughput(b, || {
        std::hint::black_box(net.classify_block_u8(&views).unwrap());
    });
    let label = format!("binary classify_block_u8 (net C, B={b})");
    println!("  {label:<44} {}", sps.format_rate("samp/s"));
    record("binary", "sps", "samples/s", true, true, &sps);

    // counters from one metered pass over the same block; the invariant
    // (every plane word either visited or skipped) is also enforced by
    // the property tests — asserting here keeps the bench honest too
    let (_, ops) = net.classify_block_u8_ops(&views).unwrap();
    let total = net.plane_words_total();
    assert_eq!(
        ops.plane_words_visited + ops.plane_words_skipped,
        total,
        "ops accounting must cover every plane word"
    );
    let frac = ops.skipped_frac();
    println!(
        "  plane words: {} visited + {} skipped of {total} ({:.1}% skipped), {} taps, {} adds",
        ops.plane_words_visited,
        ops.plane_words_skipped,
        100.0 * frac,
        ops.taps,
        ops.adds
    );
    assert!(frac > 0.0, "synth net C skipped no plane words — occupancy masks inert?");
    record(
        "binary",
        "plane_words_skipped_frac",
        "frac",
        true,
        true,
        &Measurement::from_values(vec![frac; 4], 0),
    );
    write_doc("binary");
}

/// Artifact pack/unpack timing + compressed bytes per weight on a
/// net-A-shaped synthetic model; emits `BENCH_artifact.json`.
///
/// Two metrics gate: `bytes_per_weight` (deterministic — recorded as a
/// zero-variance sample set so bench-compare judges it by exact mean
/// shift) guards the CWRS rate advantage, and `decode_us` times the
/// cold-start streamed decode (`read_sparse_model`, the range-decoder →
/// pulse-stream path the registry serves from).
fn bench_artifact() {
    use pvqnet::artifact::{read_model, read_sparse_model, write_model};
    use pvqnet::compress::Codec;
    use pvqnet::nn::Model;

    let spec = ModelSpec::by_name("a").unwrap();
    let model = Model::synth(&spec, 42);
    let q = quantize(&model, &spec.paper_ratios(), RhoMode::Norm).unwrap();
    let path = std::env::temp_dir().join("pvqnet_bench_artifact.pvqm");

    let manifest = write_model(&path, &q.quant_model).unwrap();
    let (back, _) = read_model(&path).unwrap();
    assert_eq!(back.spec, q.quant_model.spec);

    println!(
        "  {} params → {} bytes on disk, {:.3} bits/weight ({:.1}x vs f32)",
        manifest.total_params,
        manifest.total_compressed(),
        manifest.bits_per_weight(),
        manifest.total_raw() as f64 / manifest.total_compressed().max(1) as f64
    );
    for l in &manifest.layers {
        println!(
            "    {:<6} codec {:<11} {:>9} B  {:.3} bits/w",
            l.label,
            l.codec.name(),
            l.compressed_bytes,
            l.bits_per_weight()
        );
    }
    let cwrs_layers = manifest.layers.iter().filter(|l| l.codec == Codec::Cwrs).count();
    println!(
        "  CWRS won best-of on {cwrs_layers}/{} weight layers",
        manifest.layers.len()
    );

    // deterministic size metrics: identical samples → zero variance →
    // bench-compare's exact-shift verdict; bytes_per_weight is the
    // gated one (a fatter artifact is a real regression), the rest are
    // informational scalars
    let bpw = manifest.total_compressed() as f64 / manifest.total_params.max(1) as f64;
    record(
        "artifact",
        "bytes_per_weight",
        "bytes",
        false,
        true,
        &Measurement::from_values(vec![bpw; 4], 0),
    );
    record_scalar("artifact", "bits_per_weight", "bits", false, manifest.bits_per_weight());
    record_scalar(
        "artifact",
        "compressed_bytes",
        "bytes",
        false,
        manifest.total_compressed() as f64,
    );
    record_scalar("artifact", "cwrs_layers", "layers", true, cwrs_layers as f64);

    let m_pack = proto().measure(|| {
        std::hint::black_box(write_model(&path, &q.quant_model).unwrap());
    });
    println!("  {:<44} {}", "artifact pack (net A synth)", m_pack.format_time());
    record("artifact", "pack_ms", "ms", false, false, &m_pack.clone().scaled(1e3));
    let m_unpack = proto().measure(|| {
        std::hint::black_box(read_model(&path).unwrap());
    });
    println!("  {:<44} {}", "artifact unpack (net A synth)", m_unpack.format_time());
    record("artifact", "unpack_ms", "ms", false, false, &m_unpack.clone().scaled(1e3));
    // the serving cold-start path: stream ranks straight into sparse
    // layer layouts, no dense intermediate — this is the load the
    // registry does on register_artifact, so it gates
    let m_decode = proto().measure(|| {
        std::hint::black_box(read_sparse_model(&path).unwrap());
    });
    println!("  {:<44} {}", "artifact streamed decode (net A synth)", m_decode.format_time());
    record("artifact", "decode_us", "us", false, true, &m_decode.clone().scaled(1e6));
    write_doc("artifact");
    let _ = std::fs::remove_file(&path);
}

/// PJRT vs native engines, batched (net A).
fn bench_pjrt() {
    if !have_artifacts() {
        println!("  SKIP (run `make artifacts`)");
        return;
    }
    let hlo = pvqnet::runtime::HloModel::load(Path::new("artifacts/net_a.hlo.txt"), 32, 784, 10)
        .unwrap();
    let data = Dataset::load(Path::new("artifacts/mnist_test.bin")).unwrap();
    let mut x = vec![0f32; 32 * 784];
    for i in 0..32 {
        for (j, &b) in data.sample(i).iter().enumerate() {
            x[i * 784 + j] = b as f32;
        }
    }
    time_it("PJRT HLO batch-32 forward (net A)", || {
        std::hint::black_box(hlo.run_batch(&x).unwrap());
    });
    let Some((model, _)) = load_net("a") else { return };
    let samples: Vec<Tensor> = (0..32).map(|i| data.sample_f32(i, true)).collect();
    time_it("rust float engine ×32 forwards (net A)", || {
        for s in &samples {
            std::hint::black_box(pvqnet::nn::forward(&model, s));
        }
    });
}

// ------------------------------------------------------------------- main

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    // --baseline-out FILE: merge every recorded metric into one
    // platform-stamped document (the bench-compare candidate); strip
    // the flag and its value before treating positionals as filters
    let mut baseline_out: Option<String> = None;
    if let Some(i) = args.iter().position(|a| a == "--baseline-out") {
        if i + 1 < args.len() {
            baseline_out = Some(args.remove(i + 1));
        }
        args.remove(i);
    }
    let filter: Vec<&String> = args.iter().filter(|a| !a.starts_with('-')).collect();
    let experiments: Vec<(&str, fn())> = vec![
        ("table1", || bench_tables("a")),
        ("table2", || bench_tables("b")),
        ("table3", || bench_tables("c")),
        ("table4", || bench_tables("d")),
        ("acc_a", || bench_acc("a")),
        ("acc_b", || bench_acc("b")),
        ("acc_c", || bench_acc("c")),
        ("acc_d", || bench_acc("d")),
        ("table5", || bench_dist("a")),
        ("table6", || bench_dist("b")),
        ("table7", || bench_dist("c")),
        ("table8", || bench_dist("d")),
        ("golomb", bench_golomb),
        ("fig1", bench_fig1),
        ("fig2", bench_fig2),
        ("fig3", bench_fig3),
        ("opcount", bench_opcount),
        ("ablation_rho", bench_ablation_rho),
        ("ablation_group", bench_ablation_group),
        ("encode", bench_encode),
        ("engines", bench_engines),
        ("serve", bench_serve),
        ("http", bench_http),
        ("batch", bench_batch),
        ("shard", bench_shard),
        ("binary", bench_binary),
        ("loadgen", bench_loadgen),
        ("trace", bench_trace),
        ("artifact", bench_artifact),
        ("pjrt", bench_pjrt),
    ];
    if args.iter().any(|a| a == "--smoke") {
        SMOKE.store(true, std::sync::atomic::Ordering::Relaxed);
    }
    if args.iter().any(|a| a == "--list") {
        for (name, _) in &experiments {
            println!("{name}");
        }
        return;
    }
    let plat = platform();
    println!("platform: {}", plat.render());
    for w in &plat.warnings {
        println!("  warning: {w}");
    }
    if smoke() {
        println!("mode: --smoke (single iteration, numbers are statistically void)");
    } else {
        println!(
            "protocol: micro {}w+{}i · macro {}w+{}i (Tukey-filtered, Student-t 95% CIs)",
            Protocol::MICRO.warmup,
            Protocol::MICRO.iters,
            Protocol::MACRO.warmup,
            Protocol::MACRO.iters
        );
    }
    for (name, f) in experiments {
        if filter.is_empty() || filter.iter().any(|f2| name.contains(f2.as_str())) {
            println!("\n=== {name} ===");
            f();
        }
    }
    if let Some(out) = baseline_out {
        let metrics = RECORDED.lock().unwrap().clone();
        let doc = BenchDoc {
            experiment: None,
            advisory: false,
            note: Some(format!(
                "recorded by `cargo bench -- --baseline-out` ({} metrics)",
                metrics.len()
            )),
            platform: Some(platform()),
            metrics,
        };
        doc.save(Path::new(&out)).unwrap();
        println!("\nwrote merged baseline candidate {out}");
    }
}
