#!/usr/bin/env python3
"""Generate the golden PVQL conformance vectors in this directory.

This is an *independent* implementation of the PVQL compressed-layer
blob, written from the normative spec (docs/PVQM_FORMAT.md §4), not
from the Rust code. The checked-in `golden_*.pvql` files it produces
are the conformance contract: `rust/tests/pvqm_conformance.rs` asserts
that the Rust codecs re-encode the canonical vectors to these exact
bytes and decode them back bitwise-equal. If either implementation
drifts from the spec, the conformance test goes red.

Run from this directory:  python3 gen_golden.py
"""

import struct

# ------------------------------------------------------------- bit I/O
# MSB-first bit order (§4.2: JPEG/H.264 convention).


class BitWriter:
    def __init__(self):
        self.buf = bytearray()
        self.bit_pos = 0

    def put_bit(self, bit):
        if self.bit_pos == 0:
            self.buf.append(0)
        if bit:
            self.buf[-1] |= 1 << (7 - self.bit_pos)
        self.bit_pos = (self.bit_pos + 1) % 8

    def put_bits(self, v, n):
        for i in range(n - 1, -1, -1):
            self.put_bit(((v >> i) & 1) == 1)

    def finish(self):
        return bytes(self.buf)


# ------------------------------------------------- §4.2 exp-Golomb


def zigzag(v):
    # codeNum = 2|v| − [v > 0]
    return 2 * v - 1 if v > 0 else -2 * v


def write_ue(w, m):
    x = m + 1
    nbits = x.bit_length()
    w.put_bits(0, nbits - 1)
    w.put_bits(x, nbits)


def write_se(w, v):
    write_ue(w, zigzag(v))


def eg_encode(values):
    w = BitWriter()
    for v in values:
        write_se(w, v)
    return w.finish()


# ------------------------------------------------------ §4.3 zero-RLE


def rle_encode(values):
    w = BitWriter()
    run = 0
    for v in values:
        if v == 0:
            run += 1
        else:
            write_ue(w, run)
            # se′: v > 0 codes se(v − 1), v < 0 codes se(v)
            write_se(w, v - 1 if v > 0 else v)
            run = 0
    write_ue(w, run)  # tail run
    return w.finish()


# ---------------------------------------------------------- §4.4 raw


def raw_encode(values):
    return b"".join(struct.pack("<i", v) for v in values)


# ------------------------------------- §4.5 canonical Huffman, V = 7

V = 7
NSYM = 2 * V + 2  # {−V..V} ∪ {ESCAPE}; symbol s = v+V, ESCAPE = 2V+1


def huff_lengths(freq):
    """Huffman code lengths via a min-heap ordered by (weight, tie),
    tie = smallest symbol index in the subtree (spec §4.5 step 1)."""
    import heapq

    present = [s for s in range(NSYM) if freq[s] > 0]
    lengths = [0] * NSYM
    if not present:
        return lengths
    if len(present) == 1:
        lengths[present[0]] = 1
        return lengths
    parent = list(range(2 * NSYM))
    heap = [(freq[s], s, s) for s in present]  # (weight, tie, node id)
    heapq.heapify(heap)
    next_id = NSYM
    while len(heap) > 1:
        wa, ta, ia = heapq.heappop(heap)
        wb, tb, ib = heapq.heappop(heap)
        parent[ia] = next_id
        parent[ib] = next_id
        parent[next_id] = next_id
        heapq.heappush(heap, (wa + wb, min(ta, tb), next_id))
        next_id += 1
    root = heap[0][2]
    for s in present:
        d, n = 0, s
        while n != root:
            n = parent[n]
            d += 1
        lengths[s] = d
    return lengths


def huff_codes(lengths):
    """Canonicalization (spec §4.5 step 2): sort present symbols by
    (length, symbol), assign increasing codes, shift on length change."""
    order = sorted(
        (s for s in range(NSYM) if lengths[s] > 0), key=lambda s: (lengths[s], s)
    )
    codes = [0] * NSYM
    code, prev = 0, 0
    for s in order:
        code <<= lengths[s] - prev
        codes[s] = code
        code += 1
        prev = lengths[s]
    return codes


def huff_encode(values):
    freq = [0] * NSYM
    for v in values:
        freq[v + V if abs(v) <= V else NSYM - 1] += 1
    lengths = huff_lengths(freq)
    codes = huff_codes(lengths)
    w = BitWriter()
    for v in values:
        if abs(v) <= V:
            w.put_bits(codes[v + V], lengths[v + V])
        else:
            esc = NSYM - 1
            w.put_bits(codes[esc], lengths[esc])
            w.put_bits(v & 0xFFFFFFFF, 32)  # raw 32-bit two's complement
    return freq, w.finish()


# ------------------------------------------------- §4 container frame


def container(codec_id, components, k, rho, payload, extra=b""):
    out = bytearray(b"PVQL")
    out.append(codec_id)
    out += struct.pack("<I", len(components))
    out += struct.pack("<I", k)
    out += struct.pack("<d", rho)
    out += extra
    out += struct.pack("<I", len(payload))
    out += payload
    return bytes(out)


# --------------------------------------------------------- self-tests

_w = BitWriter()
_w.put_bits(0b101, 3)
assert _w.finish() == b"\xa0", "MSB-first layout"
assert eg_encode([0]) == b"\x80", "se(0) is the single bit 1"
# §4.2 code lengths: 0→1 bit, ±1→3, ±2/±3→5, ±4..±7→7
for v, bits in [(0, 1), (1, 3), (-1, 3), (2, 5), (-3, 5), (4, 7), (-7, 7)]:
    w = BitWriter()
    write_se(w, v)
    assert len(w.buf) * 8 - (8 - w.bit_pos) % 8 >= 0
    total = (len(w.buf) - 1) * 8 + (w.bit_pos or 8)
    assert total == bits, (v, total, bits)
# degenerate single-symbol table: 1 bit per symbol
freq, payload = huff_encode([0] * 50)
assert len(payload) == (50 + 7) // 8

# ------------------------------------------------- canonical vectors

# One vector shared by exp-Golomb / RLE / raw (zeros, ±1, ±2, a 3):
SHARED = [0, 0, 3, 0, -1, 1, 0, 0, -2, 0, 0, 1]
SHARED_K = sum(abs(v) for v in SHARED)  # 8
SHARED_RHO = 0.75  # exact in binary

# Huffman's vector adds escape values (|v| > 7):
HUFF = [0, 9, 0, -1, 1, 0, 0, -2, 0, 0, -9, 1]
HUFF_K = sum(abs(v) for v in HUFF)  # 23
HUFF_RHO = 0.5

golden = {
    "golden_expgolomb.pvql": container(0, SHARED, SHARED_K, SHARED_RHO, eg_encode(SHARED)),
    "golden_rle.pvql": container(1, SHARED, SHARED_K, SHARED_RHO, rle_encode(SHARED)),
    "golden_raw.pvql": container(3, SHARED, SHARED_K, SHARED_RHO, raw_encode(SHARED)),
}
freq, payload = huff_encode(HUFF)
extra = b"".join(struct.pack("<I", f) for f in freq)
golden["golden_huffman.pvql"] = container(2, HUFF, HUFF_K, HUFF_RHO, payload, extra)

if __name__ == "__main__":
    for name, data in golden.items():
        with open(name, "wb") as f:
            f.write(data)
        print(f"{name}: {len(data)} bytes  {data.hex()}")
