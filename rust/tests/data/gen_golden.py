#!/usr/bin/env python3
"""Generate the golden PVQL conformance vectors in this directory.

This is an *independent* implementation of the PVQL compressed-layer
blob, written from the normative spec (docs/PVQM_FORMAT.md §4), not
from the Rust code. The checked-in `golden_*.pvql` files it produces
are the conformance contract: `rust/tests/pvqm_conformance.rs` asserts
that the Rust codecs re-encode the canonical vectors to these exact
bytes and decode them back bitwise-equal. If either implementation
drifts from the spec, the conformance test goes red.

Run from this directory:  python3 gen_golden.py
"""

import struct

# ------------------------------------------------------------- bit I/O
# MSB-first bit order (§4.2: JPEG/H.264 convention).


class BitWriter:
    def __init__(self):
        self.buf = bytearray()
        self.bit_pos = 0

    def put_bit(self, bit):
        if self.bit_pos == 0:
            self.buf.append(0)
        if bit:
            self.buf[-1] |= 1 << (7 - self.bit_pos)
        self.bit_pos = (self.bit_pos + 1) % 8

    def put_bits(self, v, n):
        for i in range(n - 1, -1, -1):
            self.put_bit(((v >> i) & 1) == 1)

    def finish(self):
        return bytes(self.buf)


# ------------------------------------------------- §4.2 exp-Golomb


def zigzag(v):
    # codeNum = 2|v| − [v > 0]
    return 2 * v - 1 if v > 0 else -2 * v


def write_ue(w, m):
    x = m + 1
    nbits = x.bit_length()
    w.put_bits(0, nbits - 1)
    w.put_bits(x, nbits)


def write_se(w, v):
    write_ue(w, zigzag(v))


def eg_encode(values):
    w = BitWriter()
    for v in values:
        write_se(w, v)
    return w.finish()


# ------------------------------------------------------ §4.3 zero-RLE


def rle_encode(values):
    w = BitWriter()
    run = 0
    for v in values:
        if v == 0:
            run += 1
        else:
            write_ue(w, run)
            # se′: v > 0 codes se(v − 1), v < 0 codes se(v)
            write_se(w, v - 1 if v > 0 else v)
            run = 0
    write_ue(w, run)  # tail run
    return w.finish()


# ---------------------------------------------------------- §4.4 raw


def raw_encode(values):
    return b"".join(struct.pack("<i", v) for v in values)


# ------------------------------------- §4.5 canonical Huffman, V = 7

V = 7
NSYM = 2 * V + 2  # {−V..V} ∪ {ESCAPE}; symbol s = v+V, ESCAPE = 2V+1


def huff_lengths(freq):
    """Huffman code lengths via a min-heap ordered by (weight, tie),
    tie = smallest symbol index in the subtree (spec §4.5 step 1)."""
    import heapq

    present = [s for s in range(NSYM) if freq[s] > 0]
    lengths = [0] * NSYM
    if not present:
        return lengths
    if len(present) == 1:
        lengths[present[0]] = 1
        return lengths
    parent = list(range(2 * NSYM))
    heap = [(freq[s], s, s) for s in present]  # (weight, tie, node id)
    heapq.heapify(heap)
    next_id = NSYM
    while len(heap) > 1:
        wa, ta, ia = heapq.heappop(heap)
        wb, tb, ib = heapq.heappop(heap)
        parent[ia] = next_id
        parent[ib] = next_id
        parent[next_id] = next_id
        heapq.heappush(heap, (wa + wb, min(ta, tb), next_id))
        next_id += 1
    root = heap[0][2]
    for s in present:
        d, n = 0, s
        while n != root:
            n = parent[n]
            d += 1
        lengths[s] = d
    return lengths


def huff_codes(lengths):
    """Canonicalization (spec §4.5 step 2): sort present symbols by
    (length, symbol), assign increasing codes, shift on length change."""
    order = sorted(
        (s for s in range(NSYM) if lengths[s] > 0), key=lambda s: (lengths[s], s)
    )
    codes = [0] * NSYM
    code, prev = 0, 0
    for s in order:
        code <<= lengths[s] - prev
        codes[s] = code
        code += 1
        prev = lengths[s]
    return codes


def huff_encode(values):
    freq = [0] * NSYM
    for v in values:
        freq[v + V if abs(v) <= V else NSYM - 1] += 1
    lengths = huff_lengths(freq)
    codes = huff_codes(lengths)
    w = BitWriter()
    for v in values:
        if abs(v) <= V:
            w.put_bits(codes[v + V], lengths[v + V])
        else:
            esc = NSYM - 1
            w.put_bits(codes[esc], lengths[esc])
            w.put_bits(v & 0xFFFFFFFF, 32)  # raw 32-bit two's complement
    return freq, w.finish()


# ----------------------------------------- §4.6 grouped CWRS (codec 4)
#
# One LZMA-style carry-counting range-coder stream per layer. The layer
# is cut into groups of `group` components; each group codes its pulse
# budget k_g as exp-Golomb inside the stream, then either the group's
# Fischer rank within P(n_g, k_g) (k_g ≤ K_TABLE_MAX) or, as a
# fallback, per-component zigzag exp-Golomb.

CWRS_TOP = 1 << 24
CWRS_FT_MAX_BITS = 16
CWRS_K_TABLE_MAX = 512
CWRS_GROUP = 128


def Np(n, k, _memo={}):
    """Fischer's point count N_p(n,k), exact (Python int)."""
    if k == 0:
        return 1
    if n == 0:
        return 0
    key = (n, k)
    if key not in _memo:
        _memo[key] = Np(n - 1, k) + Np(n - 1, k - 1) + Np(n, k - 1)
    return _memo[key]


def cwrs_zigzag(v):
    # i32 → even/odd unsigned; i32::MIN (magnitude 2^31) stays exact
    return (v << 1) if v >= 0 else ((-v) << 1) - 1


def cwrs_unzigzag(m):
    return (m >> 1) if m % 2 == 0 else -((m + 1) >> 1)


def vector_to_index(y):
    """Canonical Fischer rank: smaller |component| first, then + before −."""
    n = len(y)
    k_rem = sum(abs(v) for v in y)
    index = 0
    for j, v in enumerate(y):
        if k_rem == 0:
            break
        dims_after = n - j - 1
        mag = abs(v)
        for w in range(mag):
            c = Np(dims_after, k_rem - w)
            index += c if w == 0 else 2 * c
        if v < 0:
            index += Np(dims_after, k_rem - mag)
        k_rem -= mag
    return index


def index_to_vector(index, n, k):
    """Inverse rank walk (mirrors the spec's decode procedure block-by-block)."""
    y = [0] * n
    rem = index
    k_rem = k
    for j in range(n):
        if k_rem == 0:
            break
        dims_after = n - j - 1
        mag, neg = 0, False
        while True:
            block = Np(dims_after, k_rem - mag)
            if mag == 0:
                if rem < block:
                    break
                rem -= block
                mag += 1
            else:
                if rem < block:
                    break
                if rem < 2 * block:
                    rem -= block
                    neg = True
                    break
                rem -= 2 * block
                mag += 1
        if mag:
            y[j] = -mag if neg else mag
        k_rem -= mag
    return y


class RangeEncoder:
    """LZMA-style carry-counting byte range coder (§4.6 state machine)."""

    def __init__(self):
        self.buf = bytearray()
        self.low = 0
        self.range = 0xFFFFFFFF
        self.cache = 0
        self.cache_size = 1

    def _shift_low(self):
        # flush unless the outgoing byte is 0xFF with no carry resolved
        if (self.low & 0xFFFFFFFF) < 0xFF000000 or (self.low >> 32) != 0:
            carry = self.low >> 32
            self.buf.append((self.cache + carry) & 0xFF)
            for _ in range(self.cache_size - 1):
                self.buf.append((0xFF + carry) & 0xFF)
            self.cache = (self.low >> 24) & 0xFF
            self.cache_size = 0
        self.cache_size += 1
        self.low = (self.low & 0x00FFFFFF) << 8

    def encode(self, v, ft):
        assert 1 <= ft <= (1 << CWRS_FT_MAX_BITS) and 0 <= v < ft
        if ft == 1:
            return
        r = self.range // ft
        self.low += r * v
        # the last symbol absorbs the division slack
        self.range = self.range - r * v if v == ft - 1 else r
        while self.range < CWRS_TOP:
            self._shift_low()
            self.range <<= 8

    def enc_bits(self, v, n):
        rem = n
        while rem > 0:
            chunk = min(rem, CWRS_FT_MAX_BITS)
            rem -= chunk
            self.encode((v >> rem) & ((1 << chunk) - 1), 1 << chunk)

    def enc_ue64(self, m):
        # every unary flag — including the terminating 1 — is its own
        # binary symbol so the decoder's decode(2) reads stay in
        # lockstep (the slack-absorption rule makes a fused
        # encode(x, 2^nb) a different state trajectory)
        x = m + 1
        nb = x.bit_length()
        for _ in range(nb - 1):
            self.encode(0, 2)
        self.encode(1, 2)
        if nb > 1:
            self.enc_bits(x & ((1 << (nb - 1)) - 1), nb - 1)

    def enc_rank(self, rank, total):
        mx = total - 1
        ftb = mx.bit_length()
        if ftb == 0:
            return  # total == 1: rank is necessarily 0
        if ftb <= CWRS_FT_MAX_BITS:
            self.encode(rank, total)
        else:
            b = ftb - CWRS_FT_MAX_BITS
            self.encode(rank >> b, (mx >> b) + 1)
            rem = b
            while rem > 0:
                chunk = min(rem, CWRS_FT_MAX_BITS)
                rem -= chunk
                self.enc_bits((rank >> rem) & ((1 << chunk) - 1), chunk)

    def finish(self):
        for _ in range(5):
            self._shift_low()
        return bytes(self.buf)


class RangeDecoder:
    """Decoder twin of RangeEncoder (used by the self-tests below)."""

    def __init__(self, payload):
        self.data = payload
        self.pos = 0
        self._byte()  # spurious leading zero byte (LZMA convention)
        self.range = 0xFFFFFFFF
        self.code = 0
        for _ in range(4):
            self.code = (self.code << 8) | self._byte()

    def _byte(self):
        # past end-of-stream reads as 0 (truncation decodes to garbage
        # that the invariant checks reject)
        b = self.data[self.pos] if self.pos < len(self.data) else 0
        self.pos += 1
        return b

    def decode(self, ft):
        if ft == 1:
            return 0
        r = self.range // ft
        v = min(self.code // r, ft - 1)
        self.code -= r * v
        self.range = self.range - r * v if v == ft - 1 else r
        while self.range < CWRS_TOP:
            self.code = ((self.code << 8) | self._byte()) & 0xFFFFFFFF
            self.range <<= 8
        return v

    def dec_bits(self, n):
        out, rem = 0, n
        while rem > 0:
            chunk = min(rem, CWRS_FT_MAX_BITS)
            rem -= chunk
            out |= self.decode(1 << chunk) << rem
        return out

    def dec_ue64(self):
        zeros = 0
        while self.decode(2) == 0:
            zeros += 1
            assert zeros <= 63, "exp-golomb unary overflow"
        rest = self.dec_bits(zeros)
        return ((1 << zeros) | rest) - 1

    def dec_rank(self, total):
        mx = total - 1
        ftb = mx.bit_length()
        if ftb == 0:
            return 0
        if ftb <= CWRS_FT_MAX_BITS:
            rank = self.decode(total)
        else:
            b = ftb - CWRS_FT_MAX_BITS
            rank = self.decode((mx >> b) + 1) << b
            rem = b
            while rem > 0:
                chunk = min(rem, CWRS_FT_MAX_BITS)
                rem -= chunk
                rank |= self.dec_bits(chunk) << rem
        assert rank < total, "rank out of range"
        return rank


def cwrs_encode(values, group=CWRS_GROUP):
    enc = RangeEncoder()
    for base in range(0, len(values), group):
        sl = values[base : base + group]
        k_g = sum(abs(v) for v in sl)
        enc.enc_ue64(k_g)
        if k_g == 0:
            continue
        if k_g > CWRS_K_TABLE_MAX:
            for v in sl:
                enc.enc_ue64(cwrs_zigzag(v))
        else:
            enc.enc_rank(vector_to_index(sl), Np(len(sl), k_g))
    return enc.finish()


def cwrs_decode(payload, n, group=CWRS_GROUP):
    dec = RangeDecoder(payload)
    out = [0] * n
    base = 0
    while base < n:
        n_g = min(group, n - base)
        k_g = dec.dec_ue64()
        if k_g == 0:
            base += n_g
            continue
        if k_g > CWRS_K_TABLE_MAX:
            s = 0
            for j in range(n_g):
                v = cwrs_unzigzag(dec.dec_ue64())
                out[base + j] = v
                s += abs(v)
            assert s == k_g, "group pulse sum mismatch"
        else:
            rank = dec.dec_rank(Np(n_g, k_g))
            for j, v in enumerate(index_to_vector(rank, n_g, k_g)):
                out[base + j] = v
        base += n_g
    return out


# ------------------------------------------------- §4 container frame


def container(codec_id, components, k, rho, payload, extra=b""):
    out = bytearray(b"PVQL")
    out.append(codec_id)
    out += struct.pack("<I", len(components))
    out += struct.pack("<I", k)
    out += struct.pack("<d", rho)
    out += extra
    out += struct.pack("<I", len(payload))
    out += payload
    return bytes(out)


# --------------------------------------------------------- self-tests

_w = BitWriter()
_w.put_bits(0b101, 3)
assert _w.finish() == b"\xa0", "MSB-first layout"
assert eg_encode([0]) == b"\x80", "se(0) is the single bit 1"
# §4.2 code lengths: 0→1 bit, ±1→3, ±2/±3→5, ±4..±7→7
for v, bits in [(0, 1), (1, 3), (-1, 3), (2, 5), (-3, 5), (4, 7), (-7, 7)]:
    w = BitWriter()
    write_se(w, v)
    assert len(w.buf) * 8 - (8 - w.bit_pos) % 8 >= 0
    total = (len(w.buf) - 1) * 8 + (w.bit_pos or 8)
    assert total == bits, (v, total, bits)
# degenerate single-symbol table: 1 bit per symbol
freq, payload = huff_encode([0] * 50)
assert len(payload) == (50 + 7) // 8
# §4.6 CWRS: paper's anchor count, first byte convention, round trips
assert Np(8, 4) == 2816, "Fischer count N_p(8,4)"
_c = cwrs_encode([0, 0, 3, 0, -1, 1, 0, 0, -2, 0, 0, 1])
assert _c[0] == 0, "range-coder streams start with a zero byte"
assert cwrs_decode(_c, 12) == [0, 0, 3, 0, -1, 1, 0, 0, -2, 0, 0, 1]
assert cwrs_decode(cwrs_encode([0] * 9, 4), 9, 4) == [0] * 9
_fb = [600, 0, -3]  # k_g > K_TABLE_MAX → zigzag fallback branch
assert cwrs_decode(cwrs_encode(_fb, 4), 3, 4) == _fb
_bd = [-(2**31), 2**31 - 1]  # i32-boundary magnitudes stay exact
assert cwrs_decode(cwrs_encode(_bd, 2), 2, 2) == _bd
# rank bijection on a small pyramid
for _i in range(Np(4, 3)):
    assert vector_to_index(index_to_vector(_i, 4, 3)) == _i

# ------------------------------------------------- canonical vectors

# One vector shared by exp-Golomb / RLE / raw (zeros, ±1, ±2, a 3):
SHARED = [0, 0, 3, 0, -1, 1, 0, 0, -2, 0, 0, 1]
SHARED_K = sum(abs(v) for v in SHARED)  # 8
SHARED_RHO = 0.75  # exact in binary

# Huffman's vector adds escape values (|v| > 7):
HUFF = [0, 9, 0, -1, 1, 0, 0, -2, 0, 0, -9, 1]
HUFF_K = sum(abs(v) for v in HUFF)  # 23
HUFF_RHO = 0.5

golden = {
    "golden_expgolomb.pvql": container(0, SHARED, SHARED_K, SHARED_RHO, eg_encode(SHARED)),
    "golden_rle.pvql": container(1, SHARED, SHARED_K, SHARED_RHO, rle_encode(SHARED)),
    "golden_raw.pvql": container(3, SHARED, SHARED_K, SHARED_RHO, raw_encode(SHARED)),
}
freq, payload = huff_encode(HUFF)
extra = b"".join(struct.pack("<I", f) for f in freq)
golden["golden_huffman.pvql"] = container(2, HUFF, HUFF_K, HUFF_RHO, payload, extra)
# CWRS codes the shared vector as one group (n = 12 ≤ group = 128); the
# codec extra byte is the writer's group width.
golden["golden_cwrs.pvql"] = container(
    4, SHARED, SHARED_K, SHARED_RHO, cwrs_encode(SHARED), extra=bytes([CWRS_GROUP])
)

if __name__ == "__main__":
    for name, data in golden.items():
        with open(name, "wb") as f:
            f.write(data)
        print(f"{name}: {len(data)} bytes  {data.hex()}")
