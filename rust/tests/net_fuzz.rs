//! Seeded property/fuzz tests for `coordinator::net` — the HTTP
//! request parser and the JSON decoder, the two components that eat
//! raw attacker-controlled bytes off the wire.
//!
//! Properties:
//! * chunking invariance — a valid request parses identically no
//!   matter how the TCP layer fragments it;
//! * no panic — mutated, truncated, or random bytes must produce
//!   `Ok`/`Err`, never a panic (`testkit::check` turns any panic into
//!   a failing case with its replay seed).

use pvqnet::coordinator::net::{HttpConn, Json, RecvError};
use pvqnet::testkit::http::loopback_pair;
use pvqnet::testkit::{check, Rng};
use std::io::Write;
use std::sync::atomic::AtomicBool;

/// Parse `raw` server-side after writing it in the given chunk sizes.
fn parse_chunked(raw: &[u8], chunks: &[usize]) -> Result<ParsedReq, String> {
    let (mut client, server) = loopback_pair();
    let raw = raw.to_vec();
    let chunks = chunks.to_vec();
    let writer = std::thread::spawn(move || {
        let mut pos = 0;
        for &c in &chunks {
            let end = (pos + c).min(raw.len());
            if pos >= end {
                break;
            }
            client.write_all(&raw[pos..end]).expect("client write");
            client.flush().expect("client flush");
            pos = end;
        }
        if pos < raw.len() {
            client.write_all(&raw[pos..]).expect("client write tail");
        }
        // signal EOF so an incomplete request resolves immediately as
        // Malformed/Closed instead of waiting out the read deadline,
        // but keep the socket alive until the parse finishes
        let _ = client.shutdown(std::net::Shutdown::Write);
        client
    });
    let mut conn = HttpConn::new(server).expect("wrap server stream");
    let stop = AtomicBool::new(false);
    let result = match conn.next_request(1 << 20, &stop) {
        Ok(r) => Ok(ParsedReq {
            method: r.method,
            path: r.path,
            headers: r.headers,
            body: r.body,
            keep_alive: r.keep_alive,
        }),
        Err(RecvError::Malformed(m)) => Err(format!("malformed: {m}")),
        Err(RecvError::BodyTooLarge) => Err("body too large".into()),
        Err(RecvError::TimedOut) => Err("timed out".into()),
        Err(RecvError::Closed) => Err("closed".into()),
        Err(RecvError::Io(e)) => Err(format!("io: {e}")),
    };
    drop(conn);
    let _ = writer.join().expect("writer thread");
    result
}

#[derive(Debug, PartialEq)]
struct ParsedReq {
    method: String,
    path: String,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
    keep_alive: bool,
}

/// Render a valid request with seeded method/path/headers/body.
fn random_valid_request(rng: &mut Rng) -> Vec<u8> {
    let methods = ["GET", "POST", "PUT", "DELETE"];
    let method = methods[rng.below(methods.len() as u64) as usize];
    let path = format!("/v{}/classify{}", rng.below(9), "x".repeat(rng.below(20) as usize));
    let body: Vec<u8> = (0..rng.below(200) as usize)
        .map(|_| rng.below(256) as u8)
        .collect();
    let mut raw = format!("{method} {path} HTTP/1.1\r\nHost: fuzz\r\n").into_bytes();
    for h in 0..rng.below(4) {
        raw.extend_from_slice(
            format!("X-Fuzz-{h}: v{}\r\n", rng.below(1000)).as_bytes(),
        );
    }
    if rng.below(2) == 0 {
        raw.extend_from_slice(b"Connection: close\r\n");
    }
    raw.extend_from_slice(format!("Content-Length: {}\r\n\r\n", body.len()).as_bytes());
    raw.extend_from_slice(&body);
    raw
}

/// Seeded chunk split of `len` bytes into 1..=8 fragments.
fn random_chunks(rng: &mut Rng, len: usize) -> Vec<usize> {
    let n = 1 + rng.below(8) as usize;
    (0..n).map(|_| 1 + rng.below(len.max(1) as u64) as usize).collect()
}

#[test]
fn chunk_boundary_splits_parse_identically() {
    check("chunking invariance", 0xC0FFEE, 40, |_, rng| {
        let raw = random_valid_request(rng);
        let whole = parse_chunked(&raw, &[raw.len()]).expect("valid request must parse");
        let chunks = random_chunks(rng, raw.len());
        let split = parse_chunked(&raw, &chunks).expect("chunked request must parse");
        assert_eq!(whole, split, "chunks {chunks:?}");
        // pathological fragmentation: one byte at a time
        let bytes = vec![1usize; raw.len()];
        let trickled = parse_chunked(&raw, &bytes).expect("byte-trickled request must parse");
        assert_eq!(whole, trickled);
    });
}

#[test]
fn mutated_requests_never_panic_the_parser() {
    check("request mutation safety", 0xBADF00D, 60, |_, rng| {
        let mut raw = random_valid_request(rng);
        // 1–4 random byte mutations anywhere in the request
        for _ in 0..=rng.below(4) {
            let at = rng.below(raw.len() as u64) as usize;
            match rng.below(3) {
                0 => raw[at] = rng.below(256) as u8,
                1 => raw.truncate(at.max(1)),
                _ => raw.insert(at, rng.below(256) as u8),
            }
        }
        // outcome may be Ok (benign mutation) or Err — never a panic;
        // NOTE: no-unwrap-reachable-from-wire-input is exactly what
        // this asserts, since check() fails the case on any panic
        let _ = parse_chunked(&raw, &[raw.len()]);
    });
}

#[test]
fn random_bytes_never_panic_the_parser() {
    check("request garbage safety", 0xF00D, 40, |_, rng| {
        let len = 1 + rng.below(300) as usize;
        let mut raw: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        // half the cases get a head terminator so body paths also run
        if rng.below(2) == 0 {
            raw.extend_from_slice(b"\r\n\r\n");
        }
        let _ = parse_chunked(&raw, &[raw.len()]);
    });
}

#[test]
fn mutated_json_never_panics_the_decoder() {
    check("json mutation safety", 0x1057, 200, |_, rng| {
        // a valid classify-shaped document…
        let pixels: Vec<String> =
            (0..rng.below(30)).map(|_| rng.below(256).to_string()).collect();
        let valid = format!(
            "{{\"model\":\"m{}\",\"pixels\":[{}],\"nested\":{{\"a\":[1,{{\"b\":null}}]}}}}",
            rng.below(10),
            pixels.join(",")
        );
        assert!(Json::parse(&valid).is_ok(), "{valid}");
        // …mutated at 1–3 seeded positions (operating on chars keeps it
        // valid UTF-8, which is what reaches the decoder — http.rs
        // rejects non-UTF-8 bodies before parsing)
        let mut chars: Vec<char> = valid.chars().collect();
        for _ in 0..=rng.below(3) {
            let at = rng.below(chars.len() as u64) as usize;
            match rng.below(3) {
                0 => chars[at] = char::from_u32(32 + rng.below(95) as u32).unwrap(),
                1 => {
                    chars.truncate(at.max(1));
                }
                _ => chars.insert(at, ['{', '}', '[', ']', '"', '\\', 'u'][rng.below(7) as usize]),
            }
        }
        let mutated: String = chars.into_iter().collect();
        let _ = Json::parse(&mutated); // Ok or Err, never a panic
    });
}

#[test]
fn adversarial_json_shapes_never_panic() {
    // hand-picked nasties the random mutator is unlikely to hit
    for bad in [
        "\\u",
        "\"\\uD800\\u0041\"",
        "\"\\uDC00\"",
        "{\"a\":1e999}",
        "-",
        "+",
        "0x10",
        "1e",
        "[1,2,3",
        &"[".repeat(100_000),
        &format!("{}1{}", "[".repeat(31), "]".repeat(31)),
        "{\"\":{\"\":{\"\":{}}}}",
        "\"\\",
        "\u{FEFF}{}",
    ] {
        let _ = Json::parse(bad);
    }
    // deep-but-legal nesting right at the cap parses without overflow
    let depth_ok = format!("{}0{}", "[".repeat(30), "]".repeat(30));
    assert!(Json::parse(&depth_ok).is_ok());
}
