//! Cross-language golden test: the rust encoder must reproduce the python
//! reference encoder (python/compile/pvq.py) bit-for-bit on shared cases.
//!
//! Requires `make artifacts` (which writes artifacts/pvq_golden.txt); the
//! test is skipped with a notice when artifacts are absent so `cargo test`
//! stays runnable on a fresh checkout.

use pvqnet::pvq::{encode, PvqVector};
use std::path::Path;

fn parse_golden(text: &str) -> Vec<(Vec<f64>, u32, Vec<i32>, f64)> {
    let mut lines = text.lines().filter(|l| !l.starts_with('#') && !l.trim().is_empty());
    let mut cases = Vec::new();
    while let Some(header) = lines.next() {
        let mut it = header.split_whitespace();
        let n: usize = it.next().unwrap().parse().unwrap();
        let k: u32 = it.next().unwrap().parse().unwrap();
        let v: Vec<f64> = lines
            .next()
            .unwrap()
            .split_whitespace()
            .map(|t| t.parse().unwrap())
            .collect();
        let comps: Vec<i32> = lines
            .next()
            .unwrap()
            .split_whitespace()
            .map(|t| t.parse().unwrap())
            .collect();
        let rho: f64 = lines.next().unwrap().parse().unwrap();
        assert_eq!(v.len(), n);
        assert_eq!(comps.len(), n);
        cases.push((v, k, comps, rho));
    }
    cases
}

#[test]
fn rust_encoder_matches_python_reference() {
    let path = Path::new("artifacts/pvq_golden.txt");
    if !path.exists() {
        eprintln!("SKIP golden_pvq: {} missing (run `make artifacts`)", path.display());
        return;
    }
    let text = std::fs::read_to_string(path).unwrap();
    let cases = parse_golden(&text);
    assert!(cases.len() >= 30, "golden file too small: {} cases", cases.len());
    for (i, (v, k, comps, rho)) in cases.iter().enumerate() {
        let q: PvqVector = encode(v, *k);
        assert_eq!(
            &q.components, comps,
            "case {i}: components diverge (n={} k={k})",
            v.len()
        );
        assert!(
            (q.rho - rho).abs() <= 1e-12 * rho.abs().max(1.0),
            "case {i}: rho {} vs python {}",
            q.rho,
            rho
        );
    }
    println!("golden_pvq: {} cases matched exactly", cases.len());
}
