//! Property tests for the log-linear latency histogram
//! (`loadgen::hist`): quantiles vs an exact sorted-samples reference
//! across seeded distributions (uniform, bimodal, heavy-tail), bounding
//! the relative bucket error at the documented 1/16, plus the
//! merge-then-query == query-then-merge invariant the per-client
//! histograms rely on.

use pvqnet::loadgen::Histogram;
use pvqnet::testkit::{check, Rng};

const QS: [f64; 5] = [0.25, 0.5, 0.9, 0.99, 0.999];

/// One seeded sample from distribution family `dist` (clamped below
/// 2³¹µs — inside the histogram's documented full-resolution range).
fn draw(dist: usize, rng: &mut Rng) -> u64 {
    let v = match dist {
        // uniform: the whole range matters equally
        0 => rng.below(100_000),
        // bimodal: a fast mode and a slow mode, nothing in between
        // (the shape that makes coarse log2 buckets lie about p50)
        1 => {
            if rng.next_u64() & 1 == 0 {
                200 + rng.below(100)
            } else {
                50_000 + rng.below(20_000)
            }
        }
        // heavy tail: Pareto-ish 100/(1−u)², the p999-dominating shape
        _ => {
            let u = rng.next_f64().min(0.999_999);
            (100.0 / ((1.0 - u) * (1.0 - u))) as u64
        }
    };
    v.min(1 << 31)
}

/// Exact reference with the histogram's own rank semantics: the
/// `ceil(q·n)`-th smallest sample (1-indexed, clamped into range).
fn exact_quantile(sorted: &[u64], q: f64) -> u64 {
    let n = sorted.len();
    let target = ((q * n as f64).ceil() as usize).clamp(1, n);
    sorted[target - 1]
}

#[test]
fn quantiles_match_sorted_reference_within_bucket_error() {
    check("hist quantile error bound", 0xB0C4, 60, |id, rng| {
        let dist = (id % 3) as usize;
        let n = 50 + rng.below(2000) as usize;
        let samples: Vec<u64> = (0..n).map(|_| draw(dist, rng)).collect();
        let mut h = Histogram::new();
        for &v in &samples {
            h.record_us(v);
        }
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        for q in QS {
            let exact = exact_quantile(&sorted, q);
            let approx = h.quantile_us(q);
            // the histogram reports the lower edge of the bucket
            // holding the rank-q sample: never above the exact value,
            // never further below than one 1/16 sub-bucket (+1 for
            // integer edges)
            assert!(
                approx <= exact,
                "dist {dist} n {n} q {q}: approx {approx} > exact {exact}"
            );
            assert!(
                (exact - approx) as f64 <= exact as f64 / 16.0 + 1.0,
                "dist {dist} n {n} q {q}: approx {approx} vs exact {exact} \
                 breaks the 1/16 relative bound"
            );
        }
        // quantiles are monotone in q
        let qs: Vec<u64> = QS.iter().map(|&q| h.quantile_us(q)).collect();
        assert!(qs.windows(2).all(|w| w[0] <= w[1]), "{qs:?}");
        // count/max/mean agree with the raw samples exactly
        assert_eq!(h.count(), n as u64);
        assert_eq!(h.max_us(), *sorted.last().unwrap());
        let mean = samples.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
        assert!((h.mean_us() - mean).abs() < 1e-6 * mean.max(1.0));
    });
}

#[test]
fn merge_then_query_equals_query_then_merge() {
    check("hist merge invariant", 0x536C, 40, |id, rng| {
        let dist = (id % 3) as usize;
        let n = 20 + rng.below(1500) as usize;
        let shards = 1 + rng.below(7) as usize;
        let samples: Vec<u64> = (0..n).map(|_| draw(dist, rng)).collect();

        // record everything into one histogram…
        let mut whole = Histogram::new();
        for &v in &samples {
            whole.record_us(v);
        }
        // …and the same stream sharded round-robin then merged
        let mut parts: Vec<Histogram> = (0..shards).map(|_| Histogram::new()).collect();
        for (i, &v) in samples.iter().enumerate() {
            parts[i % shards].record_us(v);
        }
        let mut merged = Histogram::new();
        for p in &parts {
            merged.merge(p);
        }

        assert_eq!(merged.count(), whole.count());
        assert_eq!(merged.max_us(), whole.max_us());
        assert_eq!(merged.percentiles_us(), whole.percentiles_us());
        for q in QS {
            assert_eq!(merged.quantile_us(q), whole.quantile_us(q), "q {q}");
        }
        assert!((merged.mean_us() - whole.mean_us()).abs() < 1e-9);
        assert!((merged.std_us() - whole.std_us()).abs() < 1e-9);
    });
}
