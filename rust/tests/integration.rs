//! End-to-end integration over trained artifacts: the §VII experiments as
//! assertions. Skipped when `make artifacts` has not been run.

use pvqnet::data::Dataset;
use pvqnet::nn::weights::load_model;
use pvqnet::nn::ModelSpec;
use pvqnet::pvq::RhoMode;
use pvqnet::quant::{accuracy_float, evaluate, quantize_paper_ratios};

use std::path::Path;

fn have_artifacts() -> bool {
    Path::new("artifacts/manifest.txt").exists()
}

fn load(net: &str) -> (pvqnet::nn::Model, Dataset) {
    let spec = ModelSpec::by_name(net).unwrap();
    let model =
        load_model(Path::new(&format!("artifacts/net_{net}.pvqw")), &spec).unwrap();
    let data = if spec.input_shape == vec![784] {
        Dataset::load(Path::new("artifacts/mnist_test.bin")).unwrap()
    } else {
        Dataset::load(Path::new("artifacts/cifar_test.bin")).unwrap()
    };
    (model, data)
}

#[test]
fn net_a_trained_above_chance_and_quantizes_gracefully() {
    if !have_artifacts() {
        eprintln!("SKIP integration: run `make artifacts`");
        return;
    }
    let (model, data) = load("a");
    let q = quantize_paper_ratios(&model, RhoMode::Norm).unwrap();
    let rep = evaluate(&model, &q, &data, 300).unwrap();
    println!("{}", rep.render());
    assert!(rep.before > 0.6, "net A baseline {:.3}", rep.before);
    // §VII shape: bounded drop at Table-1 ratios (N/K=5). On this
    // synthetic substrate the few-% point sits at N/K≈2 (see the trend
    // assertion below); at N/K=5 we allow a larger but bounded drop.
    assert!(
        rep.after_int >= rep.before - 0.25,
        "net A PVQ drop too large: {:.3} → {:.3}",
        rep.before,
        rep.after_int
    );
    // the paper's *few-percent* claim, at the ratio where our substrate's
    // weight redundancy matches it:
    let q2 = pvqnet::quant::quantize(&model, &[2.0, 2.0, 2.0], RhoMode::Norm).unwrap();
    let acc2 = accuracy_float(&q2.float_model, &data, 300);
    assert!(
        acc2 >= rep.before - 0.05,
        "net A at N/K=2 should drop only a few %: {:.3} → {:.3}",
        rep.before,
        acc2
    );
    assert!(rep.agreement > 0.9, "engine agreement {:.3}", rep.agreement);
    // §III op-count claim: mults collapse vs float MACs
    assert!(rep.ops.mults * 10 < rep.ops.float_macs, "mult reduction missing");
}

#[test]
fn net_c_bsign_quantizes() {
    if !have_artifacts() {
        eprintln!("SKIP integration: run `make artifacts`");
        return;
    }
    let (model, data) = load("c");
    let before = accuracy_float(&model, &data, 300);
    let q = quantize_paper_ratios(&model, RhoMode::Norm).unwrap();
    let rep = evaluate(&model, &q, &data, 300).unwrap();
    println!("{}", rep.render());
    assert!(before > 0.5, "net C baseline {before}");
    assert!(rep.after_int >= before - 0.15, "net C drop: {before} → {}", rep.after_int);
}

#[test]
fn net_b_cnn_quantizes() {
    if !have_artifacts() {
        eprintln!("SKIP integration: run `make artifacts`");
        return;
    }
    let (model, data) = load("b");
    let q = quantize_paper_ratios(&model, RhoMode::Norm).unwrap();
    // CNN integer eval is heavier — use a smaller slice
    let rep = evaluate(&model, &q, &data, 100).unwrap();
    println!("{}", rep.render());
    assert!(rep.before > 0.5, "net B baseline {:.3}", rep.before);
    assert!(
        rep.after_int >= rep.before - 0.20,
        "net B PVQ drop: {:.3} → {:.3}",
        rep.before,
        rep.after_int
    );
}

#[test]
fn net_d_bsign_cnn_quantizes() {
    if !have_artifacts() {
        eprintln!("SKIP integration: run `make artifacts`");
        return;
    }
    let (model, data) = load("d");
    let before = accuracy_float(&model, &data, 100);
    let q = quantize_paper_ratios(&model, RhoMode::Norm).unwrap();
    let rep = evaluate(&model, &q, &data, 100).unwrap();
    println!("{}", rep.render());
    // bsign CNNs are the paper's hardest case (61.6% on real CIFAR);
    // require above-chance and bounded drop
    assert!(before > 0.3, "net D baseline {before}");
    assert!(rep.after_int >= before - 0.35, "net D drop: {before} → {}", rep.after_int);
}

#[test]
fn weight_distributions_match_tables_5_8_shape() {
    if !have_artifacts() {
        eprintln!("SKIP integration: run `make artifacts`");
        return;
    }
    // Table 5 shape: FC layers at N/K=5 → ~80% zeros, ~19% ±1, <2% ±2..3
    let (model, _) = load("a");
    let q = quantize_paper_ratios(&model, RhoMode::Norm).unwrap();
    for r in &q.reports {
        let p = r.dist.percentages();
        assert!(p[0] > 65.0 && p[0] < 93.0, "{}: zeros {:.1}%", r.label, p[0]);
        assert!(p[1] > 7.0 && p[1] < 30.0, "{}: ±1 {:.1}%", r.label, p[1]);
        assert!(p[4] < 0.5, "{}: others {:.2}%", r.label, p[4]);
    }
    // Table 6 CONV1 shape (N/K=1): ~36% zeros, ~41% ±1, ~20% ±2..3
    let (model_b, _) = load("b");
    let qb = quantize_paper_ratios(&model_b, RhoMode::Norm).unwrap();
    let conv1 = &qb.reports[1];
    let p = conv1.dist.percentages();
    assert!(p[0] > 20.0 && p[0] < 55.0, "CONV1 zeros {:.1}%", p[0]);
    assert!(p[1] > 25.0 && p[1] < 55.0, "CONV1 ±1 {:.1}%", p[1]);
}

#[test]
fn compression_bits_match_section_6() {
    if !have_artifacts() {
        eprintln!("SKIP integration: run `make artifacts`");
        return;
    }
    let (model, _) = load("a");
    let q = quantize_paper_ratios(&model, RhoMode::Norm).unwrap();
    // FC0 at N/K=5: §VI computes ≈1.4 bits/weight with exp-Golomb
    let fc0 = q.quant_model.layers.iter().flatten().next().unwrap();
    let bpw = pvqnet::compress::expgolomb::bits_per_weight(&fc0.w);
    assert!(bpw > 1.0 && bpw < 1.8, "FC0 exp-Golomb {bpw:.3} b/w (paper ≈1.4)");
    // RLE beats EG on this sparse layer
    let rle = pvqnet::compress::rle::bits_per_weight(&fc0.w);
    assert!(rle < bpw, "RLE {rle:.3} should beat EG {bpw:.3}");
    // and the container round-trips losslessly
    let mut comps = fc0.w.clone();
    comps.extend_from_slice(&fc0.b_pyramid);
    let pv = pvqnet::pvq::PvqVector { k: fc0.k, components: comps, rho: fc0.rho };
    let bytes = pvqnet::compress::compress_layer(&pv, pvqnet::compress::Codec::Rle);
    let back = pvqnet::compress::decompress_layer(&bytes).unwrap();
    assert_eq!(back.components, pv.components);
}
