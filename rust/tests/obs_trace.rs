//! Observability subsystem tests: concurrent ring-buffer integrity,
//! Chrome trace-event exporter validity, and shard-span attachment.
//!
//! The ring test drains *while* writers are recording, so it exercises
//! the seqlock + generation-checksum path that the overhead contract
//! depends on: a snapshot may miss in-flight records but must never
//! yield a torn one.

use pvqnet::coordinator::net::Json;
use pvqnet::nn::parallel::{for_each_shard, ShardPlan};
use pvqnet::obs::{self, chrome_trace, Recorder, SpanRecord, Stage};

/// Records for writer `i` are pure functions of `(i, n)`, so any mix of
/// fields from two different writes breaks at least one equation.
fn record_for(i: u64, n: u64) -> SpanRecord {
    let t = ((i + 1) << 32) | n;
    SpanRecord {
        trace_id: t,
        stage: Stage::ALL[(n % 9) as usize],
        start_us: n * 3,
        dur_us: n + 7,
        track: 0, // overwritten by the ring
        model: i as u32 + 1,
        arg_a: t ^ 0xDEAD_BEEF,
        arg_b: n * 11,
        arg_c: t.wrapping_mul(3),
        arg_d: t.rotate_left(13),
        arg_e: n.wrapping_mul(17) ^ i,
    }
}

/// Every field of a drained record must satisfy the writer's invariant.
fn assert_intact(r: &SpanRecord, max_tracks: u32, writes_per_thread: u64) {
    let i = (r.trace_id >> 32) - 1;
    let n = r.trace_id & 0xFFFF_FFFF;
    assert!(n < writes_per_thread, "unknown write index {n}");
    let want = record_for(i, n);
    assert_eq!(r.stage, want.stage, "torn stage in {r:?}");
    assert_eq!(r.start_us, want.start_us, "torn start in {r:?}");
    assert_eq!(r.dur_us, want.dur_us, "torn dur in {r:?}");
    assert_eq!(r.model, want.model, "torn model in {r:?}");
    assert_eq!(r.arg_a, want.arg_a, "torn arg_a in {r:?}");
    assert_eq!(r.arg_b, want.arg_b, "torn arg_b in {r:?}");
    assert_eq!(r.arg_c, want.arg_c, "torn arg_c in {r:?}");
    assert_eq!(r.arg_d, want.arg_d, "torn arg_d in {r:?}");
    assert_eq!(r.arg_e, want.arg_e, "torn arg_e in {r:?}");
    assert!(r.track < max_tracks, "track {} out of range", r.track);
}

#[test]
fn ring_concurrent_writers_no_torn_records_bounded_memory() {
    const CAP: usize = 64;
    const MAX_RINGS: usize = 4;
    const THREADS: u64 = 6;
    const WRITES: u64 = 500;
    let rec = Recorder::with_limits(CAP, MAX_RINGS);
    std::thread::scope(|s| {
        for i in 0..THREADS {
            let rec = &rec;
            s.spawn(move || {
                // only MAX_RINGS threads win a ring; the rest must be
                // refused (bounded memory beats completeness)
                let Some(ring) = rec.register(&format!("writer-{i}")) else {
                    return;
                };
                assert_eq!(ring.capacity(), CAP);
                for n in 0..WRITES {
                    ring.record(&record_for(i, n));
                }
            });
        }
        // drain while the writers hammer their rings: every record that
        // comes out must be exactly one that went in
        for _ in 0..50 {
            for r in rec.snapshot() {
                assert_intact(&r, MAX_RINGS as u32, WRITES);
            }
        }
    });
    // quiesced: still intact, and memory stayed bounded despite each
    // writer producing WRITES >> CAP records (old spans overwritten)
    let finals = rec.snapshot();
    assert!(!finals.is_empty());
    assert!(finals.len() <= MAX_RINGS * CAP, "{} records escaped the bound", finals.len());
    for r in &finals {
        assert_intact(r, MAX_RINGS as u32, WRITES);
        // the final CAP writes of each surviving ring are the newest
        assert!((r.trace_id & 0xFFFF_FFFF) >= WRITES - CAP as u64);
    }
    assert_eq!(rec.ring_count(), MAX_RINGS);
    assert_eq!(rec.dropped_threads(), THREADS - MAX_RINGS as u64);
}

#[test]
fn exporter_emits_valid_chrome_trace_json() {
    let rec = Recorder::with_limits(32, 2);
    let ring = rec.register("conn-0").expect("first ring");
    let model = rec.intern_label("net_a");
    let spans = [
        (Stage::Accept, [96u64, 0, 0, 0, 0]),
        (Stage::Parse, [0, 0, 0, 0, 0]),
        (Stage::Queue, [3, 0, 0, 0, 0]),
        (Stage::Compute, [4, 123_456, 789, 5120, 1880]),
        (Stage::Shard, [1, 12, 40, 0, 0]),
        (Stage::Write, [210, 0, 0, 0, 0]),
    ];
    for (k, (stage, args)) in spans.iter().enumerate() {
        ring.record(&SpanRecord {
            trace_id: 7,
            stage: *stage,
            start_us: 100 * k as u64,
            dur_us: 50,
            track: 0,
            model,
            arg_a: args[0],
            arg_b: args[1],
            arg_c: args[2],
            arg_d: args[3],
            arg_e: args[4],
        });
    }
    let text = chrome_trace(&rec);
    let doc = Json::parse(&text).expect("exporter output must be valid JSON");
    assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ms"));
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_array)
        .expect("traceEvents array");
    // one thread_name metadata event + one X event per span
    let meta: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
        .collect();
    assert_eq!(meta.len(), 1);
    assert_eq!(
        meta[0].get("args").and_then(|a| a.get("name")).and_then(Json::as_str),
        Some("conn-0")
    );
    let xs: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .collect();
    assert_eq!(xs.len(), spans.len());
    for e in &xs {
        assert_eq!(e.get("cat").and_then(Json::as_str), Some("pvqnet"));
        for key in ["name", "ts", "dur", "pid", "tid"] {
            assert!(e.get(key).is_some(), "X event missing {key}: {}", e.render());
        }
        let args = e.get("args").expect("args object");
        assert_eq!(args.get("request_id"), Some(&Json::Num(7.0)));
        assert_eq!(args.get("model").and_then(Json::as_str), Some("net_a"));
    }
    // stage-specific args survive the round trip
    let by_name = |n: &str| {
        xs.iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some(n))
            .unwrap_or_else(|| panic!("no {n} event"))
            .get("args")
            .unwrap()
    };
    assert_eq!(by_name("accept").get("bytes"), Some(&Json::Num(96.0)));
    assert_eq!(by_name("queue").get("queue_depth"), Some(&Json::Num(3.0)));
    let compute = by_name("compute");
    assert_eq!(compute.get("batch"), Some(&Json::Num(4.0)));
    assert_eq!(compute.get("predicted_cycles_addonly"), Some(&Json::Num(123_456.0)));
    assert_eq!(compute.get("predicted_dots"), Some(&Json::Num(789.0)));
    assert_eq!(compute.get("plane_words_visited"), Some(&Json::Num(5120.0)));
    assert_eq!(compute.get("plane_words_skipped"), Some(&Json::Num(1880.0)));
    let shard = by_name("shard");
    assert_eq!(shard.get("rows"), Some(&Json::Num(12.0)));
    assert_eq!(shard.get("work_estimate"), Some(&Json::Num(40.0)));
}

#[test]
fn shard_spans_attach_to_ambient_request_ctx() {
    // global state: this is the only test in this binary that enables
    // tracing, so no cross-test interference inside the process
    obs::set_sampling(1);
    obs::set_enabled(true);
    let ctx = obs::request_ctx();
    assert!(ctx.sampled && ctx.id != 0);
    let plan = ShardPlan::balanced(&[10; 8], 2);
    assert_eq!(plan.shard_count(), 2);
    let mut out = vec![0i64; 8 * 2];
    obs::with_ctx(ctx, || {
        for_each_shard(&plan, &mut out, 2, |range, chunk| {
            for (ri, row) in range.enumerate() {
                chunk[ri * 2] = row as i64;
            }
        });
    });
    obs::set_enabled(false);
    let shards: Vec<_> = Recorder::global()
        .snapshot()
        .into_iter()
        .filter(|r| r.trace_id == ctx.id && r.stage == Stage::Shard)
        .collect();
    assert_eq!(shards.len(), plan.shard_count(), "one shard span per range");
    for (i, range) in plan.ranges().iter().enumerate() {
        let span = shards
            .iter()
            .find(|r| r.arg_a == i as u64)
            .unwrap_or_else(|| panic!("no span for shard {i}"));
        assert_eq!(span.arg_b, range.len() as u64);
        assert_eq!(span.arg_c, plan.range_weights()[i]);
    }
    // kernel results are untouched by tracing
    for (row, pair) in out.chunks(2).enumerate() {
        assert_eq!(pair[0], row as i64);
    }
}
