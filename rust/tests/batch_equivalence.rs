//! Batch/scalar equivalence properties for the batch-fused kernels.
//!
//! The contract of `forward_block` (CSR engine) and `forward_block_u8`
//! (binary popcount engine) is that a `B×N` panel produces **bitwise
//! identical** results to `B` independent scalar passes. Both engines
//! accumulate in `i64` in the same per-row tap order as their scalar
//! paths, so the equality is exact — stronger than the ≤1-ulp bound a
//! float accumulator would allow. The properties sweep odd shapes on
//! purpose: B=1, B=n_threads+1, feature counts that are not a multiple
//! of the 64-bit bit-plane width.
//!
//! The same contract extends to sharding (`set_shards`): each shard
//! writes a disjoint slice of the output panel, so for every shard
//! count in {1, 2, 3, 4, 8} — including counts exceeding the row count
//! — the sharded output must equal the single-shard output bit for bit.
//! The shard sweeps below enforce this for both engines, layer kinds
//! (dense/conv/pool), and the end-to-end registry serving path.

use pvqnet::coordinator::{Classify, ClassifyRequest, Engine, EngineKind, ModelRegistry, ServerConfig};
use pvqnet::nn::batch::{ActivationBlock, BitBlock};
use pvqnet::nn::binary::{BinaryDense, BinaryNet, BitVec};
use pvqnet::nn::csr_engine::CompiledQuantModel;
use pvqnet::nn::model::{Activation, LayerSpec, ModelSpec};
use pvqnet::nn::tensor::ITensor;
use pvqnet::nn::Model;
use pvqnet::pvq::RhoMode;
use pvqnet::quant::quantize;
use pvqnet::testkit::{check, Rng};
use std::sync::Arc;

/// B = one more than the machine's thread count — the "awkward" batch
/// size the issue calls out (never a power of two on common cores).
fn odd_batch() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4) + 1
}

fn random_samples(rng: &mut Rng, b: usize, len: usize) -> Vec<Vec<u8>> {
    (0..b).map(|_| (0..len).map(|_| rng.below(256) as u8).collect()).collect()
}

#[test]
fn prop_csr_mlp_block_bitwise_identical() {
    check("csr-mlp-batch-vs-scalar", 9001, 12, |_, rng| {
        // deliberately odd dims: not multiples of any lane width
        let d0 = 5 + rng.below(90) as usize;
        let d1 = 3 + rng.below(40) as usize;
        let d2 = 2 + rng.below(9) as usize;
        let spec = ModelSpec {
            name: "beq".into(),
            input_shape: vec![d0],
            layers: vec![
                LayerSpec::Scale(1.0 / 255.0),
                LayerSpec::Dense { input: d0, output: d1, act: Activation::Relu },
                LayerSpec::Dense { input: d1, output: d2, act: Activation::None },
            ],
        };
        let model = Model::synth(&spec, rng.next_u64());
        let q = quantize(&model, &[3.0, 2.0], RhoMode::Norm).unwrap();
        let compiled = CompiledQuantModel::compile(&q.quant_model).unwrap();
        for b in [1usize, odd_batch()] {
            let samples = random_samples(rng, b, d0);
            let views: Vec<&[u8]> = samples.iter().map(|s| s.as_slice()).collect();
            let block = ActivationBlock::from_samples_u8(&views).unwrap();
            let got = compiled.forward_block(&block).unwrap();
            assert_eq!(got.batch(), b);
            for (s, sample) in samples.iter().enumerate() {
                let want = compiled.forward(&ITensor::from_u8(&[d0], sample));
                assert_eq!(got.row(s), want, "B={b} sample {s}");
            }
        }
    });
}

#[test]
fn csr_cnn_block_bitwise_identical() {
    // conv + pool + flatten + dense: the full CompiledLayer alphabet
    let spec = ModelSpec {
        name: "beqc".into(),
        input_shape: vec![7, 7, 2], // odd image side → floor pool
        layers: vec![
            LayerSpec::Scale(1.0 / 255.0),
            LayerSpec::Conv2d { kh: 3, kw: 3, cin: 2, cout: 5, act: Activation::Relu },
            LayerSpec::MaxPool2x2,
            LayerSpec::Flatten,
            LayerSpec::Dense { input: 3 * 3 * 5, output: 4, act: Activation::None },
        ],
    };
    let model = Model::synth(&spec, 7);
    let q = quantize(&model, &[1.0, 2.0], RhoMode::Norm).unwrap();
    let compiled = CompiledQuantModel::compile(&q.quant_model).unwrap();
    let mut rng = Rng::new(8);
    for b in [1usize, odd_batch(), 16] {
        let samples = random_samples(&mut rng, b, 7 * 7 * 2);
        let views: Vec<&[u8]> = samples.iter().map(|s| s.as_slice()).collect();
        let block = ActivationBlock::from_samples_u8(&views).unwrap();
        let logits = compiled.forward_block(&block).unwrap();
        let classes = compiled.classify_block(&block).unwrap();
        for (s, sample) in samples.iter().enumerate() {
            let t = ITensor::from_u8(&[7, 7, 2], sample);
            assert_eq!(logits.row(s), compiled.forward(&t), "B={b} sample {s}");
            assert_eq!(classes[s], compiled.classify(&t), "B={b} sample {s}");
        }
    }
}

#[test]
fn prop_binary_net_block_bitwise_identical() {
    check("binary-batch-vs-scalar", 9002, 10, |_, rng| {
        // widths straddle the 64-bit plane boundary on purpose
        let d0 = 40 + rng.below(60) as usize; // 40..99
        let d1 = 50 + rng.below(40) as usize; // 50..89: hidden bit-planes
        let d2 = 30 + rng.below(40) as usize;
        let d3 = 2 + rng.below(8) as usize;
        let spec = ModelSpec {
            name: "beqb".into(),
            input_shape: vec![d0],
            layers: vec![
                LayerSpec::Dense { input: d0, output: d1, act: Activation::BSign },
                LayerSpec::Dense { input: d1, output: d2, act: Activation::BSign },
                LayerSpec::Dense { input: d2, output: d3, act: Activation::None },
            ],
        };
        let model = Model::synth(&spec, rng.next_u64());
        let qm = quantize(&model, &[2.0, 2.0, 1.0], RhoMode::Norm).unwrap().quant_model;
        let net = BinaryNet::compile(&qm).unwrap();
        for b in [1usize, odd_batch()] {
            let samples = random_samples(rng, b, d0);
            let views: Vec<&[u8]> = samples.iter().map(|s| s.as_slice()).collect();
            let got = net.forward_block_u8(&views).unwrap();
            let classes = net.classify_block_u8(&views).unwrap();
            for (s, sample) in samples.iter().enumerate() {
                assert_eq!(got[s], net.forward_u8(sample).unwrap(), "B={b} sample {s}");
                assert_eq!(classes[s], net.classify_u8(sample).unwrap(), "B={b} sample {s}");
            }
            // the metered path is the same kernel: identical logits,
            // and its skip accounting covers every plane word exactly
            let (metered, ops) = net.forward_block_u8_ops(&views).unwrap();
            assert_eq!(metered, got, "B={b} metered logits drifted");
            assert_eq!(
                ops.plane_words_visited + ops.plane_words_skipped,
                net.plane_words_total(),
                "B={b} ops accounting leak: {ops:?}"
            );
        }
    });
}

#[test]
fn prop_binary_dense_block_matches_scalar_rows() {
    // the layer-level kernel on its own, across ±1 inputs with partial
    // trailing words
    check("binary-dense-block", 9003, 15, |_, rng| {
        let input = 1 + rng.below(200) as usize;
        let output = 1 + rng.below(30) as usize;
        let w: Vec<i32> = (0..input * output)
            .map(|_| match rng.below(10) {
                0..=5 => 0,
                6 => 1,
                7 => -1,
                8 => 2,
                _ => -3,
            })
            .collect();
        let bias: Vec<i32> = (0..output).map(|_| (rng.below(5) as i32) - 2).collect();
        let bd = BinaryDense::compile(&w, &bias, input, output);
        let b = 1 + rng.below(12) as usize;
        let rows: Vec<Vec<i64>> = (0..b)
            .map(|_| (0..input).map(|_| if rng.next_u64() & 1 == 1 { 1 } else { -1 }).collect())
            .collect();
        let blk = BitBlock::from_pm1_rows(&rows).unwrap();
        let y = bd.forward_block(&blk);
        for (s, row) in rows.iter().enumerate() {
            let want = bd.forward(&BitVec::from_pm1(row).unwrap());
            let got: Vec<i64> = (0..output).map(|o| y[o * b + s]).collect();
            assert_eq!(got, want, "sample {s}");
        }
        // bsign chaining matches the scalar repack too
        let chained = bd.forward_bsign_block(&blk);
        for (s, row) in rows.iter().enumerate() {
            let want = bd.forward_bsign(&BitVec::from_pm1(row).unwrap()).to_pm1();
            assert_eq!(chained.row_pm1(s), want, "sample {s}");
        }
    });
}

/// The acceptance sweep: shard counts {1, 2, 3, 4, 8} (3 never divides
/// power-of-two row counts evenly; 8 usually exceeds the layer widths
/// here, exercising the fewer-shards-than-requested fallback).
const SHARD_SWEEP: [usize; 5] = [1, 2, 3, 4, 8];

#[test]
fn prop_csr_sharded_bitwise_identical() {
    check("csr-shard-sweep", 9101, 8, |_, rng| {
        // odd dims: row counts never divisible by the shard counts
        let d0 = 5 + rng.below(90) as usize;
        let d1 = 3 + rng.below(40) as usize;
        let d2 = 2 + rng.below(9) as usize;
        let spec = ModelSpec {
            name: "shq".into(),
            input_shape: vec![d0],
            layers: vec![
                LayerSpec::Dense { input: d0, output: d1, act: Activation::Relu },
                LayerSpec::Dense { input: d1, output: d2, act: Activation::None },
            ],
        };
        let model = Model::synth(&spec, rng.next_u64());
        let q = quantize(&model, &[3.0, 2.0], RhoMode::Norm).unwrap();
        let mut compiled = CompiledQuantModel::compile(&q.quant_model).unwrap();
        for b in [1usize, odd_batch()] {
            let samples = random_samples(rng, b, d0);
            let views: Vec<&[u8]> = samples.iter().map(|s| s.as_slice()).collect();
            let block = ActivationBlock::from_samples_u8(&views).unwrap();
            compiled.set_shards(1);
            let want = compiled.forward_block(&block).unwrap();
            // single-shard block path equals the scalar path…
            for (s, sample) in samples.iter().enumerate() {
                let scalar = compiled.forward(&ITensor::from_u8(&[d0], sample));
                assert_eq!(want.row(s), scalar, "B={b} sample {s}");
            }
            // …and every sharded run equals it bit for bit
            for shards in SHARD_SWEEP {
                compiled.set_shards(shards);
                let got = compiled.forward_block(&block).unwrap();
                assert_eq!(got, want, "B={b} shards={shards}");
            }
        }
    });
}

#[test]
fn csr_cnn_sharded_bitwise_identical() {
    // conv + pool + flatten + dense with an odd 7×7 image: the conv
    // plan splits 7 rows, the pool plan 3 — neither divisible by the
    // even shard counts
    let spec = ModelSpec {
        name: "shqc".into(),
        input_shape: vec![7, 7, 2],
        layers: vec![
            LayerSpec::Scale(1.0 / 255.0),
            LayerSpec::Conv2d { kh: 3, kw: 3, cin: 2, cout: 5, act: Activation::Relu },
            LayerSpec::MaxPool2x2,
            LayerSpec::Flatten,
            LayerSpec::Dense { input: 3 * 3 * 5, output: 4, act: Activation::None },
        ],
    };
    let model = Model::synth(&spec, 41);
    let q = quantize(&model, &[1.0, 2.0], RhoMode::Norm).unwrap();
    let mut compiled = CompiledQuantModel::compile(&q.quant_model).unwrap();
    let mut rng = Rng::new(42);
    for b in [1usize, odd_batch(), 16] {
        let samples = random_samples(&mut rng, b, 7 * 7 * 2);
        let views: Vec<&[u8]> = samples.iter().map(|s| s.as_slice()).collect();
        let block = ActivationBlock::from_samples_u8(&views).unwrap();
        compiled.set_shards(1);
        let want = compiled.forward_block(&block).unwrap();
        for shards in SHARD_SWEEP {
            compiled.set_shards(shards);
            assert_eq!(compiled.forward_block(&block).unwrap(), want, "B={b} shards={shards}");
            assert_eq!(
                compiled.classify_block(&block).unwrap(),
                want.argmax_rows(),
                "B={b} shards={shards}"
            );
        }
    }
}

#[test]
fn prop_binary_sharded_bitwise_identical() {
    check("binary-shard-sweep", 9102, 6, |_, rng| {
        // widths straddle the 64-bit plane boundary on purpose
        let d0 = 40 + rng.below(60) as usize;
        let d1 = 50 + rng.below(40) as usize;
        let d2 = 30 + rng.below(40) as usize;
        let d3 = 2 + rng.below(8) as usize;
        let spec = ModelSpec {
            name: "shqb".into(),
            input_shape: vec![d0],
            layers: vec![
                LayerSpec::Dense { input: d0, output: d1, act: Activation::BSign },
                LayerSpec::Dense { input: d1, output: d2, act: Activation::BSign },
                LayerSpec::Dense { input: d2, output: d3, act: Activation::None },
            ],
        };
        let model = Model::synth(&spec, rng.next_u64());
        let qm = quantize(&model, &[2.0, 2.0, 1.0], RhoMode::Norm).unwrap().quant_model;
        let mut net = BinaryNet::compile(&qm).unwrap();
        for b in [1usize, odd_batch()] {
            let samples = random_samples(rng, b, d0);
            let views: Vec<&[u8]> = samples.iter().map(|s| s.as_slice()).collect();
            net.set_shards(1);
            let (want, want_ops) = net.forward_block_u8_ops(&views).unwrap();
            for (s, sample) in samples.iter().enumerate() {
                assert_eq!(want[s], net.forward_u8(sample).unwrap(), "B={b} sample {s}");
            }
            assert_eq!(
                want_ops.plane_words_visited + want_ops.plane_words_skipped,
                net.plane_words_total(),
                "B={b} ops accounting leak: {want_ops:?}"
            );
            for shards in SHARD_SWEEP {
                net.set_shards(shards);
                // outputs are bitwise identical AND the ops counters are
                // exact — sharding repartitions the rows but must visit
                // and skip precisely the same plane words
                let (got, ops) = net.forward_block_u8_ops(&views).unwrap();
                assert_eq!(got, want, "B={b} shards={shards}");
                assert_eq!(ops, want_ops, "B={b} shards={shards} counters drifted");
            }
        }
    });
}

/// The small models above are below the planner's per-shard work floor,
/// so their plans collapse to one range. This test uses layers big
/// enough that `set_shards(8)` provably grants multiple ranges — the
/// only way to exercise the relative-vs-absolute row indexing inside
/// the sharded kernels — and re-checks bitwise identity there.
#[test]
fn large_layers_get_multi_range_plans_and_stay_bitwise_identical() {
    let mut rng = Rng::new(51);

    // dense MLP: ~12k pulses in the first layer
    let spec = ModelSpec {
        name: "shbig".into(),
        input_shape: vec![256],
        layers: vec![
            LayerSpec::Dense { input: 256, output: 96, act: Activation::Relu },
            LayerSpec::Dense { input: 96, output: 10, act: Activation::None },
        ],
    };
    let q = quantize(&Model::synth(&spec, 50), &[2.0, 1.0], RhoMode::Norm).unwrap();
    let mut compiled = CompiledQuantModel::compile(&q.quant_model).unwrap();
    compiled.set_shards(8);
    let granted = compiled.layer_shard_counts();
    assert!(granted.iter().any(|&c| c > 1), "expected multi-range plans, got {granted:?}");
    let samples = random_samples(&mut rng, 5, 256);
    let views: Vec<&[u8]> = samples.iter().map(|s| s.as_slice()).collect();
    let block = ActivationBlock::from_samples_u8(&views).unwrap();
    compiled.set_shards(1);
    let want = compiled.forward_block(&block).unwrap();
    for (s, sample) in samples.iter().enumerate() {
        assert_eq!(want.row(s), compiled.forward(&ITensor::from_u8(&[256], sample)), "sample {s}");
    }
    for shards in SHARD_SWEEP {
        compiled.set_shards(shards);
        assert_eq!(compiled.forward_block(&block).unwrap(), want, "shards={shards}");
    }

    // CNN: 32×32×4 so conv, pool, and the readout dense all clear the
    // work floor (K=N keeps every conv tap nonzero)
    let cnn = ModelSpec {
        name: "shbigc".into(),
        input_shape: vec![32, 32, 4],
        layers: vec![
            LayerSpec::Conv2d { kh: 3, kw: 3, cin: 4, cout: 4, act: Activation::Relu },
            LayerSpec::MaxPool2x2,
            LayerSpec::Flatten,
            LayerSpec::Dense { input: 16 * 16 * 4, output: 7, act: Activation::None },
        ],
    };
    let q = quantize(&Model::synth(&cnn, 52), &[1.0, 1.0], RhoMode::Norm).unwrap();
    let mut compiled = CompiledQuantModel::compile(&q.quant_model).unwrap();
    compiled.set_shards(8);
    let granted = compiled.layer_shard_counts();
    assert!(
        granted.iter().filter(|&&c| c > 1).count() >= 2,
        "expected conv and pool multi-range plans, got {granted:?}"
    );
    let samples = random_samples(&mut rng, 5, 32 * 32 * 4);
    let views: Vec<&[u8]> = samples.iter().map(|s| s.as_slice()).collect();
    let block = ActivationBlock::from_samples_u8(&views).unwrap();
    compiled.set_shards(1);
    let want = compiled.forward_block(&block).unwrap();
    for shards in SHARD_SWEEP {
        compiled.set_shards(shards);
        assert_eq!(compiled.forward_block(&block).unwrap(), want, "cnn shards={shards}");
    }

    // binary net: the 512×256 integer first layer clears the floor
    let bspec = ModelSpec {
        name: "shbigb".into(),
        input_shape: vec![512],
        layers: vec![
            LayerSpec::Dense { input: 512, output: 256, act: Activation::BSign },
            LayerSpec::Dense { input: 256, output: 64, act: Activation::BSign },
            LayerSpec::Dense { input: 64, output: 10, act: Activation::None },
        ],
    };
    let qm = quantize(&Model::synth(&bspec, 53), &[2.0, 2.0, 1.0], RhoMode::Norm)
        .unwrap()
        .quant_model;
    let mut net = BinaryNet::compile(&qm).unwrap();
    net.set_shards(8);
    let granted = net.layer_shard_counts();
    assert!(granted.iter().any(|&c| c > 1), "expected multi-range plans, got {granted:?}");
    let bsamples = random_samples(&mut rng, 3, 512);
    let bviews: Vec<&[u8]> = bsamples.iter().map(|s| s.as_slice()).collect();
    net.set_shards(1);
    let bwant = net.forward_block_u8(&bviews).unwrap();
    for (s, sample) in bsamples.iter().enumerate() {
        assert_eq!(bwant[s], net.forward_u8(sample).unwrap(), "sample {s}");
    }
    for shards in SHARD_SWEEP {
        net.set_shards(shards);
        assert_eq!(net.forward_block_u8(&bviews).unwrap(), bwant, "binary shards={shards}");
    }
}

#[test]
fn binary_dense_layer_sharded_matches() {
    // the popcount layer kernel on its own: shard counts beyond the row
    // count, partial trailing words, and a shard-count sweep per batch
    let mut rng = Rng::new(43);
    let (input, output) = (130, 11); // 3 mask words per row, 11 rows
    let w: Vec<i32> = (0..input * output)
        .map(|_| match rng.below(10) {
            0..=5 => 0,
            6 => 1,
            7 => -1,
            8 => 2,
            _ => -3,
        })
        .collect();
    let bias: Vec<i32> = (0..output).map(|_| (rng.below(5) as i32) - 2).collect();
    let mut bd = BinaryDense::compile(&w, &bias, input, output);
    let rows: Vec<Vec<i64>> = (0..5)
        .map(|_| (0..input).map(|_| if rng.next_u64() & 1 == 1 { 1 } else { -1 }).collect())
        .collect();
    let blk = BitBlock::from_pm1_rows(&rows).unwrap();
    let want = bd.forward_block(&blk);
    for shards in SHARD_SWEEP.into_iter().chain([64]) {
        bd.set_shards(shards);
        assert_eq!(bd.forward_block(&blk), want, "shards={shards}");
    }
}

#[test]
fn engine_batched_dispatch_matches_scalar_engines() {
    let spec = ModelSpec {
        name: "beqe".into(),
        input_shape: vec![33],
        layers: vec![
            LayerSpec::Dense { input: 33, output: 17, act: Activation::Relu },
            LayerSpec::Dense { input: 17, output: 6, act: Activation::None },
        ],
    };
    let model = Model::synth(&spec, 21);
    let q = quantize(&model, &[2.0, 1.0], RhoMode::Norm).unwrap();
    let compiled = Arc::new(CompiledQuantModel::compile(&q.quant_model).unwrap());
    let engine = Engine::PvqCompiled(compiled.clone(), vec![33]);
    let mut rng = Rng::new(22);
    let samples = random_samples(&mut rng, odd_batch() + 16, 33);
    let views: Vec<&[u8]> = samples.iter().map(|s| s.as_slice()).collect();
    let batched = engine.classify_batch(&views).unwrap();
    for (s, sample) in samples.iter().enumerate() {
        assert_eq!(batched[s], compiled.classify(&ITensor::from_u8(&[33], sample)));
    }

    // binary engine dispatch
    let bspec = ModelSpec {
        name: "beqeb".into(),
        input_shape: vec![70],
        layers: vec![
            LayerSpec::Dense { input: 70, output: 65, act: Activation::BSign },
            LayerSpec::Dense { input: 65, output: 5, act: Activation::None },
        ],
    };
    let bmodel = Model::synth(&bspec, 23);
    let bq = quantize(&bmodel, &[2.0, 1.0], RhoMode::Norm).unwrap().quant_model;
    let net = Arc::new(BinaryNet::compile(&bq).unwrap());
    let bengine = Engine::Binary(net.clone());
    let bsamples = random_samples(&mut rng, 9, 70);
    let bviews: Vec<&[u8]> = bsamples.iter().map(|s| s.as_slice()).collect();
    let bbatched = bengine.classify_batch(&bviews).unwrap();
    for (s, sample) in bsamples.iter().enumerate() {
        assert_eq!(bbatched[s], net.classify_u8(sample).unwrap());
    }

    // metered dispatch: only the binary engine reports plane-kernel ops
    let (classes, ops) = bengine.classify_batch_ops(&bviews).unwrap();
    assert_eq!(classes, bbatched);
    let ops = ops.expect("binary engine meters its kernels");
    assert_eq!(
        ops.plane_words_visited + ops.plane_words_skipped,
        net.plane_words_total(),
        "engine dispatch ops leak: {ops:?}"
    );
    let (_, no_ops) = engine.classify_batch_ops(&views).unwrap();
    assert!(no_ops.is_none(), "csr engine must not report zeroed BinOps");
}

#[test]
fn registry_batched_serving_matches_direct_engines() {
    // end to end: registry → server → batcher → worker → sharded
    // forward_block (shards=3 via ServerConfig), answers must equal the
    // direct (unserved, single-shard) engine for both engines
    let spec = |act, name: &str| ModelSpec {
        name: name.into(),
        input_shape: vec![48],
        layers: vec![
            LayerSpec::Dense { input: 48, output: 65, act },
            LayerSpec::Dense { input: 65, output: 7, act: Activation::None },
        ],
    };
    let relu = quantize(&Model::synth(&spec(Activation::Relu, "r"), 31), &[2.0, 1.0], RhoMode::Norm)
        .unwrap()
        .quant_model;
    let bsign =
        quantize(&Model::synth(&spec(Activation::BSign, "b"), 32), &[2.0, 1.0], RhoMode::Norm)
            .unwrap()
            .quant_model;
    let compiled = CompiledQuantModel::compile(&relu).unwrap();
    let net = BinaryNet::compile(&bsign).unwrap();

    let mut reg = ModelRegistry::new(ServerConfig { shards: 3, ..Default::default() });
    reg.register_quant("csr", relu.clone(), EngineKind::Auto, None).unwrap();
    reg.register_quant("bin", bsign.clone(), EngineKind::Auto, None).unwrap();
    // auto-selection picked the batched engines, sharded per the config
    let models = reg.models();
    assert_eq!(models[0].name, "bin");
    assert_eq!(models[0].engine, "binary");
    assert_eq!(models[1].engine, "pvq-csr");
    assert!(models.iter().all(|m| m.shards == 3));

    let mut rng = Rng::new(33);
    let samples = random_samples(&mut rng, 40, 48);
    let got_csr = reg
        .submit(ClassifyRequest::batch(samples.clone()).with_model("csr"))
        .unwrap();
    let got_bin = reg
        .submit(ClassifyRequest::batch(samples.clone()).with_model("bin"))
        .unwrap();
    assert_eq!(got_csr.model, "csr");
    assert_eq!(got_bin.model, "bin");
    for (s, sample) in samples.iter().enumerate() {
        assert_eq!(
            got_csr.results[s].class,
            compiled.classify(&ITensor::from_u8(&[48], sample))
        );
        assert_eq!(got_bin.results[s].class, net.classify_u8(sample).unwrap());
    }
    reg.shutdown();
}
