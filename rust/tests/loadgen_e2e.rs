//! End-to-end tests for the `loadgen` harness: seeded reproducibility,
//! zero-unanswered accounting under faults and shutdown-mid-flight,
//! and the bitwise oracle over both drive paths. Loopback sockets only.

use pvqnet::coordinator::{HttpConfig, ServerConfig};
use pvqnet::loadgen::{
    build_registry, run, ArrivalLaw, LoadConfig, LoadPlan, Oracle, TrafficShape,
};
use std::time::Duration;

/// A small, fast config shared by the e2e runs.
fn base_cfg(seed: u64) -> LoadConfig {
    LoadConfig {
        seed,
        requests: 72,
        shape: TrafficShape::Closed { clients: 3 },
        drive_http: true,
        drive_inproc: true,
        fault_every: 6,
        drain_after: None,
        server: ServerConfig::default(),
        http: HttpConfig::default(),
        read_timeout: Duration::from_secs(10),
        model_seed: 42,
        trace: false,
    }
}

#[test]
fn same_seed_reproduces_the_exact_request_stream() {
    let cfg = base_cfg(1234);
    let plan_cfg = pvqnet::loadgen::PlanConfig {
        requests: cfg.requests,
        input_len: pvqnet::loadgen::INPUT_LEN,
        models: LoadConfig::model_names(),
        fault_every: cfg.fault_every,
        max_batch_body: 6,
        shape: cfg.shape,
    };
    let a = LoadPlan::generate(cfg.seed, &plan_cfg);
    let b = LoadPlan::generate(cfg.seed, &plan_cfg);
    assert_eq!(a, b, "same seed must derive the identical plan");
    for (ra, rb) in a.requests.iter().zip(&b.requests) {
        assert_eq!(ra.body(), rb.body(), "request {} bytes differ", ra.index);
        assert_eq!(ra.fault, rb.fault);
    }
    let c = LoadPlan::generate(cfg.seed + 1, &plan_cfg);
    assert_ne!(a, c, "different seeds must differ");
}

#[test]
fn faulted_http_run_answers_everything_and_verifies_bitwise() {
    let report = run(&base_cfg(7)).unwrap();
    let http = report.http.as_ref().expect("http path driven");
    let inproc = report.inproc.as_ref().expect("inproc path driven");

    for p in [http, inproc] {
        assert_eq!(p.sent as usize, p.planned, "[{}] every request attempted", p.label);
        assert_eq!(p.accounted(), p.sent, "[{}] outcome buckets must sum to sent", p.label);
        assert_eq!(p.unanswered, 0, "[{}] swallowed requests: {}", p.label, p.unanswered);
        assert_eq!(
            p.oracle_mismatches, 0,
            "[{}] oracle mismatches: {:?}",
            p.label, p.mismatch_examples
        );
        assert!(p.oracle_checked > 0, "[{}] oracle never ran", p.label);
        assert!(p.ok > 0, "[{}] no successful requests", p.label);
    }
    // the fault schedule actually ran on the wire and got its answers
    assert!(http.fault_answered > 0, "no injected fault was answered");
    assert!(http.aborted > 0, "disconnect-mid-body faults never aborted");
    assert!(report.passed());
    // latency histogram saw every fault-free 200
    assert_eq!(http.hist.count(), http.ok);
    // server-side accounting is visible in the report
    assert!(http.http_admitted > 0);
    // two model servers plus the "http" front-end pseudo-entry
    assert_eq!(http.model_stats.len(), 3);
    assert!(http.model_stats.iter().any(|m| m.name == "http"));
    // JSON output is well-formed for the CI artifact
    let json = report.to_json();
    assert!(pvqnet::coordinator::net::Json::parse(json.trim()).is_ok(), "{json}");
    assert!(json.contains("\"passed\":true"));
}

#[test]
fn shutdown_mid_flight_still_accounts_for_every_request() {
    let cfg = LoadConfig {
        drain_after: Some(0.5),
        drive_inproc: false,
        ..base_cfg(11)
    };
    let report = run(&cfg).unwrap();
    let http = report.http.as_ref().unwrap();
    assert_eq!(http.sent as usize, http.planned);
    assert_eq!(http.accounted(), http.sent);
    assert_eq!(http.unanswered, 0, "drain swallowed requests");
    assert_eq!(http.oracle_mismatches, 0, "{:?}", http.mismatch_examples);
    // the drain actually interrupted the run: some requests resolved as
    // explicit refusals / clean closes / drain rejections
    assert!(
        http.refused + http.closed_clean + http.rejected > 0,
        "drain never interfered: {http:?}"
    );
    assert!(report.passed());
}

#[test]
fn open_loop_poisson_run_paces_and_verifies() {
    let cfg = LoadConfig {
        requests: 48,
        shape: TrafficShape::Open { rps: 400.0, arrivals: ArrivalLaw::Poisson },
        drive_inproc: false,
        fault_every: 8,
        ..base_cfg(21)
    };
    let report = run(&cfg).unwrap();
    let http = report.http.as_ref().unwrap();
    assert_eq!(http.sent as usize, http.planned);
    assert_eq!(http.unanswered, 0);
    assert_eq!(http.oracle_mismatches, 0, "{:?}", http.mismatch_examples);
    // 48 arrivals at 400rps ≈ 120ms of pacing: wall time reflects it
    assert!(http.wall_s >= 0.08, "open loop did not pace: {}s", http.wall_s);
    assert!(report.passed());
}

#[test]
fn traced_run_has_complete_span_chains_under_faults_and_drain() {
    // faults + shutdown-mid-flight + tracing: every answered 200 must
    // still carry a complete accept→write span chain
    let cfg = LoadConfig {
        trace: true,
        drain_after: Some(0.7),
        drive_inproc: false,
        ..base_cfg(61)
    };
    let report = run(&cfg).unwrap();
    let http = report.http.as_ref().unwrap();
    assert_eq!(http.unanswered, 0);
    assert_eq!(http.oracle_mismatches, 0, "{:?}", http.mismatch_examples);
    let trace = http.trace.as_ref().expect("traced run must carry a TraceCheck");
    assert!(trace.checked > 0, "no request ids reached the clients — tracing never engaged");
    assert_eq!(
        trace.complete, trace.checked,
        "incomplete span chains: {:?}",
        trace.missing_examples
    );
    assert!(report.passed());
    // the run's trace exports as valid Chrome trace-event JSON
    let doc = pvqnet::coordinator::net::Json::parse(&pvqnet::obs::export_global())
        .expect("chrome trace must parse");
    assert!(doc.get("traceEvents").is_some());
    // front-end stage percentiles ride along as the "http" pseudo-model
    assert!(
        http.model_stats.iter().any(|m| m.name == "http" && !m.stages.is_empty()),
        "front-end parse/write stage stats missing: {:?}",
        http.model_stats
    );
    // the oracle-checked 200s are in the trace gate's denominator
    assert!(trace.checked >= http.ok, "{} checked < {} ok", trace.checked, http.ok);
}

#[test]
fn oracle_engines_are_the_served_instances() {
    // the oracle must hold the same Arc'd engines the registry serves —
    // pointer equality, not just value agreement
    let cfg = base_cfg(31);
    let reg = build_registry(&cfg).unwrap();
    let direct = reg.engine(Some("m0")).unwrap();
    let again = reg.engine(Some("m0")).unwrap();
    assert!(std::sync::Arc::ptr_eq(&direct, &again));
    let by_default = reg.engine(None).unwrap();
    assert!(std::sync::Arc::ptr_eq(&direct, &by_default), "default route is m0");
    assert!(reg.engine(Some("ghost")).is_none());
    let _oracle = Oracle::from_registry(&reg).unwrap();
    reg.shutdown();
}

#[test]
fn no_fault_run_is_clean_and_fast() {
    let cfg = LoadConfig {
        fault_every: 0,
        requests: 40,
        shape: TrafficShape::Closed { clients: 2 },
        drive_http: false,
        ..base_cfg(51)
    };
    let report = run(&cfg).unwrap();
    assert!(report.http.is_none());
    let inproc = report.inproc.as_ref().unwrap();
    assert_eq!(inproc.sent, 40);
    assert_eq!(inproc.ok + inproc.fault_answered, 40, "{inproc:?}");
    assert_eq!(inproc.unanswered, 0);
    assert_eq!(inproc.oracle_checked, inproc.ok);
    assert_eq!(inproc.oracle_mismatches, 0);
    assert!(report.passed());
}
