//! `.pvqm` artifact properties: encode → write → read → decode must be
//! bit-identical; truncated or corrupted inputs must error, never panic;
//! and the multi-model registry must serve several artifacts concurrently
//! through the batching server with per-model-correct predictions.

use pvqnet::artifact::{
    inspect, read_model, write_model, write_model_with_version, ArtifactReader, ArtifactWriter,
};
use pvqnet::compress::Codec;
use pvqnet::coordinator::{Classify, ClassifyRequest, EngineKind, ModelRegistry, ServerConfig};
use pvqnet::nn::model::{Activation, LayerSpec, ModelSpec};
use pvqnet::nn::{forward_int, ITensor, Model, QuantModel};
use pvqnet::pvq::RhoMode;
use pvqnet::quant::quantize;
use pvqnet::testkit::{check, Rng};
use std::path::PathBuf;
use std::sync::Arc;

fn tmp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("pvqm_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Random small MLP spec + synthetic weights, quantized at a random ratio.
fn random_quant_mlp(rng: &mut Rng, seed: u64) -> QuantModel {
    let d0 = 6 + rng.below(40) as usize;
    let d1 = 4 + rng.below(24) as usize;
    let d2 = 2 + rng.below(8) as usize;
    let act = if rng.below(2) == 0 { Activation::Relu } else { Activation::BSign };
    let spec = ModelSpec {
        name: format!("rt{seed}"),
        input_shape: vec![d0],
        layers: vec![
            LayerSpec::Scale(1.0 / 255.0),
            LayerSpec::Dense { input: d0, output: d1, act },
            LayerSpec::Dropout(0.25),
            LayerSpec::Dense { input: d1, output: d2, act: Activation::None },
        ],
    };
    let model = Model::synth(&spec, seed.wrapping_mul(0x9E37) + 1);
    let r0 = 1.0 + 4.0 * rng.next_f64();
    let r1 = 1.0 + 2.0 * rng.next_f64();
    quantize(&model, &[r0, r1], RhoMode::Norm).unwrap().quant_model
}

fn assert_models_identical(a: &QuantModel, b: &QuantModel) {
    assert_eq!(a.spec, b.spec);
    assert_eq!(a.layers.len(), b.layers.len());
    for (la, lb) in a.layers.iter().zip(&b.layers) {
        assert_eq!(la, lb); // QuantLayer: PartialEq over w, b, b_pyramid, rho, k
    }
}

#[test]
fn prop_pack_unpack_bit_identical() {
    check("pvqm-roundtrip", 2024, 25, |id, rng| {
        let qm = random_quant_mlp(rng, id);
        let path = tmp_path(&format!("prop_{id}.pvqm"));
        let manifest = write_model(&path, &qm).unwrap();
        assert_eq!(manifest.layers.len(), 2);
        let (back, manifest2) = read_model(&path).unwrap();
        assert_models_identical(&qm, &back);
        assert_eq!(manifest, manifest2);
        // the spec + manifest reachable without decoding agree too
        let (ispec, imani) = inspect(&path).unwrap();
        assert_eq!(ispec, qm.spec);
        assert_eq!(imani, manifest);
        std::fs::remove_file(&path).unwrap();
    });
}

#[test]
fn prop_conv_model_roundtrips() {
    let spec = ModelSpec {
        name: "rtconv".into(),
        input_shape: vec![8, 8, 2],
        layers: vec![
            LayerSpec::Conv2d { kh: 3, kw: 3, cin: 2, cout: 4, act: Activation::Relu },
            LayerSpec::MaxPool2x2,
            LayerSpec::Flatten,
            LayerSpec::Dense { input: 4 * 4 * 4, output: 5, act: Activation::None },
        ],
    };
    let model = Model::synth(&spec, 99);
    let qm = quantize(&model, &[1.0, 2.0], RhoMode::Norm).unwrap().quant_model;
    let path = tmp_path("conv.pvqm");
    write_model(&path, &qm).unwrap();
    let (back, _) = read_model(&path).unwrap();
    assert_models_identical(&qm, &back);
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn prop_truncation_errors_never_panics() {
    let mut rng = Rng::new(55);
    let qm = random_quant_mlp(&mut rng, 55);
    let mut buf = Vec::new();
    let mut w = ArtifactWriter::new(&mut buf, &qm.spec).unwrap();
    for (li, l) in qm.layers.iter().enumerate() {
        if let Some(q) = l {
            w.write_layer(li, q).unwrap();
        }
    }
    w.finish().unwrap();

    for cut in 0..buf.len() {
        let slice = &buf[..cut];
        let mut r = match ArtifactReader::new(slice) {
            Ok(r) => r,
            Err(_) => continue,
        };
        // header + SPEC survived the cut; draining the stream must error
        // (the ENDM marker can never be reached on a strict prefix)
        let err = loop {
            match r.next_layer() {
                Ok(Some(_)) => {}
                Ok(None) => break false,
                Err(_) => break true,
            }
        };
        assert!(err, "truncation at {cut}/{} went undetected", buf.len());
    }
}

#[test]
fn prop_corrupted_crc_errors_never_panics() {
    let mut rng = Rng::new(66);
    let qm = random_quant_mlp(&mut rng, 66);
    let mut buf = Vec::new();
    let mut w = ArtifactWriter::new(&mut buf, &qm.spec).unwrap();
    for (li, l) in qm.layers.iter().enumerate() {
        if let Some(q) = l {
            w.write_layer(li, q).unwrap();
        }
    }
    w.finish().unwrap();

    // flip a bit at every offset past the fixed header: the read must
    // fail or come back incomplete — never panic, never silently differ
    for pos in 8..buf.len() {
        let mut bad = buf.clone();
        bad[pos] ^= 0x10;
        let mut r = match ArtifactReader::new(bad.as_slice()) {
            Ok(r) => r,
            Err(_) => continue,
        };
        let mut layers = 0;
        let detected = loop {
            match r.next_layer() {
                Ok(Some(_)) => layers += 1,
                Ok(None) => break layers < 2 || r.manifest().is_none(),
                Err(_) => break true,
            }
        };
        assert!(detected, "bit flip at {pos} went undetected");
    }
}

#[test]
fn unfinished_writer_leaves_detectable_truncation() {
    let mut rng = Rng::new(77);
    let qm = random_quant_mlp(&mut rng, 77);
    let mut buf = Vec::new();
    let mut w = ArtifactWriter::new(&mut buf, &qm.spec).unwrap();
    for (li, l) in qm.layers.iter().enumerate() {
        if let Some(q) = l {
            w.write_layer(li, q).unwrap();
        }
    }
    drop(w); // no finish(): no MANI, no ENDM
    let mut r = ArtifactReader::new(buf.as_slice()).unwrap();
    let err = loop {
        match r.next_layer() {
            Ok(Some(_)) => {}
            Ok(None) => break false,
            Err(_) => break true,
        }
    };
    assert!(err, "missing ENDM must read as truncation");
}

/// Acceptance: two different `.pvqm` models served side by side through
/// the batching registry, concurrently, with per-model predictions that
/// exactly match each model's own engine run directly.
#[test]
fn registry_serves_two_models_concurrently_with_correct_predictions() {
    let spec = ModelSpec {
        name: "zoo".into(),
        input_shape: vec![20],
        layers: vec![
            LayerSpec::Dense { input: 20, output: 12, act: Activation::Relu },
            LayerSpec::Dense { input: 12, output: 6, act: Activation::None },
        ],
    };
    // fixed sample set + ground truth from each model's reference engine
    let mut rng = Rng::new(3003);
    let samples: Vec<Vec<u8>> =
        (0..60).map(|_| (0..20).map(|_| rng.below(256) as u8).collect()).collect();
    let truth = |qm: &QuantModel| -> Vec<usize> {
        samples
            .iter()
            .map(|s| {
                pvqnet::nn::tensor::argmax_i64(
                    &forward_int(qm, &ITensor::from_u8(&[20], s)).unwrap().logits,
                )
            })
            .collect()
    };

    // two genuinely different models over the same topology; models are
    // deterministic per seed, but guard against the off-chance that two
    // random nets agree on every sample by advancing the second seed
    let qa = quantize(&Model::synth(&spec, 1001), &[1.5, 1.0], RhoMode::Norm)
        .unwrap()
        .quant_model;
    let want_a = truth(&qa);
    let (qb, want_b) = (2002..2012)
        .find_map(|seed| {
            let q = quantize(&Model::synth(&spec, seed), &[1.5, 1.0], RhoMode::Norm)
                .unwrap()
                .quant_model;
            let w = truth(&q);
            (w != want_a).then_some((q, w))
        })
        .expect("ten random nets all predicting identically is implausible");

    let pa = tmp_path("zoo_a.pvqm");
    let pb = tmp_path("zoo_b.pvqm");
    write_model(&pa, &qa).unwrap();
    write_model(&pb, &qb).unwrap();

    let reg = Arc::new(
        ModelRegistry::load(
            &[&pa, &pb],
            ServerConfig {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(1),
                workers: 2,
                queue_cap: 512,
                shards: 2,
            },
        )
        .unwrap(),
    );
    let names: Vec<String> = reg.models().iter().map(|m| m.name.clone()).collect();
    assert_eq!(names, vec!["zoo_a".to_string(), "zoo_b".to_string()]);

    // hammer both models from concurrent clients
    let mut handles = Vec::new();
    for (model, want) in [("zoo_a", want_a.clone()), ("zoo_b", want_b.clone())] {
        let reg = reg.clone();
        let samples = samples.clone();
        handles.push(std::thread::spawn(move || {
            for pass in 0..3 {
                for (i, s) in samples.iter().enumerate() {
                    let reply = reg
                        .submit(ClassifyRequest::single(s.clone()).with_model(model))
                        .unwrap();
                    assert_eq!(
                        reply.results[0].class, want[i],
                        "{model} sample {i} pass {pass}: wrong prediction"
                    );
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let summary = reg.summary();
    assert!(summary.contains("[zoo_a]") && summary.contains("[zoo_b]"));
    match Arc::try_unwrap(reg) {
        Ok(r) => r.shutdown(),
        Err(_) => panic!("registry still shared after joins"),
    }
    std::fs::remove_file(&pa).unwrap();
    std::fs::remove_file(&pb).unwrap();
}

/// Acceptance for the `decode_into` load path: the same model packed as
/// a v1 artifact (dense-era codecs only) and as a v2 artifact (CWRS
/// competing, streamed into the compilers) must serve bitwise-identical
/// results — classes through the batching registry AND integer logits
/// through the direct engine oracle — and both must match a
/// reference-engine registration of the v2 file.
#[test]
fn v1_and_v2_artifacts_serve_bitwise_identical_results() {
    for (act, engine_name) in [(Activation::Relu, "pvq-csr"), (Activation::BSign, "binary")] {
        let spec = ModelSpec {
            name: "vv".into(),
            input_shape: vec![24],
            layers: vec![
                LayerSpec::Dense { input: 24, output: 14, act },
                LayerSpec::Dense { input: 14, output: 5, act: Activation::None },
            ],
        };
        let qm = quantize(&Model::synth(&spec, 41), &[2.0, 1.0], RhoMode::Norm)
            .unwrap()
            .quant_model;
        let p1 = tmp_path(&format!("vv1_{engine_name}.pvqm"));
        let p2 = tmp_path(&format!("vv2_{engine_name}.pvqm"));
        let m1 = write_model_with_version(&p1, &qm, 1).unwrap();
        let m2 = write_model(&p2, &qm).unwrap();
        // a v1 writer must never have picked CWRS; the v2 writer picks
        // it freely (and does, on these sparse layers)
        assert!(m1.layers.iter().all(|l| l.codec != Codec::Cwrs), "{engine_name}");
        assert!(m2.layers.iter().any(|l| l.codec == Codec::Cwrs), "{engine_name}");

        let mut reg = ModelRegistry::new(ServerConfig::default());
        reg.register_artifact(&p1, EngineKind::Auto).unwrap();
        reg.register_artifact(&p2, EngineKind::Auto).unwrap();
        reg.register_quant("oracle", qm.clone(), EngineKind::Reference, None).unwrap();
        for m in reg.models() {
            if m.name != "oracle" {
                assert_eq!(m.engine, engine_name, "{}", m.name);
            }
        }

        let v1_name = format!("vv1_{engine_name}");
        let v2_name = format!("vv2_{engine_name}");
        let e1 = reg.engine(Some(&v1_name)).unwrap();
        let e2 = reg.engine(Some(&v2_name)).unwrap();
        let mut rng = Rng::new(42);
        for _ in 0..30 {
            let s: Vec<u8> = (0..24).map(|_| rng.below(256) as u8).collect();
            // integer logits are bitwise-reproducible on these engines:
            // the streamed v2 load must reproduce the v1 dense-era load
            // score for score, not just argmax
            let l1 = e1.logits(&s).unwrap().expect("integer engine");
            let l2 = e2.logits(&s).unwrap().expect("integer engine");
            assert_eq!(l1, l2, "{engine_name}: logits diverge between v1 and v2 loads");
            // and the served classes agree with the reference engine
            let want = pvqnet::nn::tensor::argmax_i64(
                &forward_int(&qm, &ITensor::from_u8(&[24], &s)).unwrap().logits,
            );
            for name in [v1_name.as_str(), v2_name.as_str(), "oracle"] {
                let got = reg
                    .submit(ClassifyRequest::single(s.clone()).with_model(name))
                    .unwrap();
                assert_eq!(got.results[0].class, want, "{engine_name}/{name}");
            }
        }
        reg.shutdown();
        std::fs::remove_file(&p1).unwrap();
        std::fs::remove_file(&p2).unwrap();
    }
}

/// A bsign-MLP artifact comes back up on the binary popcount engine and
/// still agrees with the reference integer engine.
#[test]
fn registry_binary_engine_matches_reference() {
    let spec = ModelSpec {
        name: "bsrv".into(),
        input_shape: vec![16],
        layers: vec![
            LayerSpec::Dense { input: 16, output: 10, act: Activation::BSign },
            LayerSpec::Dense { input: 10, output: 4, act: Activation::None },
        ],
    };
    let qm = quantize(&Model::synth(&spec, 31), &[2.0, 1.0], RhoMode::Norm)
        .unwrap()
        .quant_model;
    let path = tmp_path("bsrv.pvqm");
    write_model(&path, &qm).unwrap();

    let mut reg = ModelRegistry::new(ServerConfig::default());
    let name = reg.register_artifact(&path, EngineKind::Auto).unwrap();
    assert_eq!(name, "bsrv");
    assert_eq!(reg.models()[0].engine, "binary");

    let mut rng = Rng::new(32);
    for _ in 0..40 {
        let s: Vec<u8> = (0..16).map(|_| rng.below(256) as u8).collect();
        let want = pvqnet::nn::tensor::argmax_i64(
            &forward_int(&qm, &ITensor::from_u8(&[16], &s)).unwrap().logits,
        );
        let got = reg
            .submit(ClassifyRequest::single(s).with_model("bsrv"))
            .unwrap();
        assert_eq!(got.results[0].class, want);
    }
    reg.shutdown();
    std::fs::remove_file(&path).unwrap();
}
