//! Golden test for `pvqnet bench-compare`: a checked-in baseline /
//! current fixture pair whose verdict table is pinned byte-for-byte —
//! one improved metric, one unchanged, one gated regression, and one
//! platform-mismatch skip. Any change to the table layout, the verdict
//! wording, or the statistics that feed them shows up as a diff here.

use pvqnet::bench::{compare, BenchDoc, Verdict};
use std::path::Path;

const BASELINE: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/data/bench_baseline.json");
const CURRENT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/data/bench_current.json");
const OTHER: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/data/bench_current_other.json");
const GOLDEN_TABLE: &str = include_str!("data/bench_verdicts.txt");

fn load_fixtures() -> (BenchDoc, Vec<BenchDoc>) {
    let baseline = BenchDoc::load(Path::new(BASELINE)).unwrap();
    let currents = vec![
        BenchDoc::load(Path::new(CURRENT)).unwrap(),
        BenchDoc::load(Path::new(OTHER)).unwrap(),
    ];
    (baseline, currents)
}

#[test]
fn fixtures_parse_as_expected() {
    let (baseline, currents) = load_fixtures();
    assert!(!baseline.advisory);
    assert_eq!(baseline.metrics.len(), 4);
    let fp = baseline.platform.as_ref().unwrap().fingerprint();
    assert_eq!(fp, "linux/x86_64/avx2");
    // same machine class as the baseline…
    assert_eq!(currents[0].platform.as_ref().unwrap().fingerprint(), fp);
    // …and a deliberately different one
    assert_eq!(currents[1].platform.as_ref().unwrap().fingerprint(), "linux/aarch64/noavx2");
}

#[test]
fn verdict_table_matches_golden_bytes() {
    let (baseline, currents) = load_fixtures();
    let cmp = compare(&baseline, &currents, 5.0);
    let rendered = cmp.render();
    assert!(
        rendered == GOLDEN_TABLE,
        "verdict table drifted from tests/data/bench_verdicts.txt\n\
         --- expected ---\n{GOLDEN_TABLE}--- got ---\n{rendered}"
    );
}

#[test]
fn verdicts_and_gate_behind_the_golden_table() {
    let (baseline, currents) = load_fixtures();
    let cmp = compare(&baseline, &currents, 5.0);
    let verdicts: Vec<(&str, Verdict)> =
        cmp.rows.iter().map(|r| (r.name.as_str(), r.verdict)).collect();
    assert_eq!(
        verdicts,
        vec![
            ("kernel_sps", Verdict::Improved),
            ("scale_sps", Verdict::Unchanged),
            ("p99_us", Verdict::Regressed),
            ("hook_ns", Verdict::PlatformSkip),
        ]
    );
    // exactly one gated hot-path regression → the gate fails…
    assert_eq!(cmp.gated_regressions(), 1);
    assert!(cmp.gate_failed());
    // …unless the baseline is advisory, which keeps the verdicts but
    // disarms the gate
    let mut advisory = baseline.clone();
    advisory.advisory = true;
    let cmp = compare(&advisory, &currents, 5.0);
    assert_eq!(cmp.rows[2].verdict, Verdict::Regressed);
    assert!(!cmp.gate_failed());
    assert!(cmp.render().contains("ADVISORY"));
    assert!(cmp.render().contains("GATE: ok"));
}

#[test]
fn effect_floor_is_live_in_the_fixture() {
    // the shard row shifts +0.2%: with the floor dropped to zero it is
    // still not significant (t ≈ 0.16), so the verdict holds — the
    // floor only matters for significant-but-tiny shifts
    let (baseline, currents) = load_fixtures();
    let cmp = compare(&baseline, &currents, 0.0);
    assert_eq!(cmp.rows[1].verdict, Verdict::Unchanged);
    // while a floor above every effect size mutes all calls
    let cmp = compare(&baseline, &currents, 50.0);
    assert_eq!(cmp.rows[0].verdict, Verdict::Unchanged);
    assert_eq!(cmp.rows[2].verdict, Verdict::Unchanged);
    assert!(!cmp.gate_failed());
}

#[test]
fn fixture_docs_roundtrip_through_the_serializer() {
    let (baseline, currents) = load_fixtures();
    for doc in std::iter::once(&baseline).chain(&currents) {
        let back = BenchDoc::parse(&doc.to_json_string()).unwrap();
        assert_eq!(&back, doc);
    }
}
