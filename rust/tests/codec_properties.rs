//! Property tests sweeping every PVQL codec across the K/N grid,
//! including the i32-boundary values from the PR 4 exp-Golomb fix, plus
//! mutation fuzz on the layer-container decoder (corrupt bytes must
//! read as errors, never panics).

use pvqnet::compress::{
    compress_layer, compress_layer_best, decompress_layer, decompress_layer_into, Codec,
    PulseSink,
};
use pvqnet::pvq::{encode_fast, PvqVector, RhoMode};
use pvqnet::testkit::{check, Rng};

/// PVQ-encode a Laplacian layer at dimension `n`, ratio `n/k_ratio`.
fn sample_layer(rng: &mut Rng, n: usize, ratio: usize) -> PvqVector {
    let v = rng.laplacian_vec(n, 0.8);
    encode_fast(&v, (n / ratio).max(1) as u32, RhoMode::Norm)
}

#[test]
fn all_codecs_roundtrip_across_the_kn_grid() {
    check("codec × K/N grid roundtrip", 0x6001D, 3, |_, rng| {
        for n in [1usize, 7, 64, 500] {
            for ratio in [1usize, 2, 5, 10] {
                let q = sample_layer(rng, n, ratio);
                for codec in Codec::ALL {
                    let bytes = compress_layer(&q, codec);
                    let back = decompress_layer(&bytes)
                        .unwrap_or_else(|e| panic!("{codec:?} N={n} N/K={ratio}: {e}"));
                    assert_eq!(back.components, q.components, "{codec:?} N={n} N/K={ratio}");
                    assert_eq!(back.k, q.k);
                    assert_eq!(back.rho.to_bits(), q.rho.to_bits(), "rho must be bit-exact");
                }
                // the best-of container the .pvqm writer uses roundtrips too
                let (_, best) = compress_layer_best(&q);
                assert_eq!(decompress_layer(&best).unwrap().components, q.components);
            }
        }
    });
}

#[test]
fn i32_boundary_components_roundtrip_every_codec() {
    // the PR 4 fix made exp-Golomb reject values outside i32 instead of
    // truncating; the exact boundaries are legal and must survive every
    // codec (Huffman routes them through its 32-bit escape)
    let boundary_layers = [
        // lone extremes: Σ|c| fits u32 (|i32::MIN| = 2^31 < 2^32)
        PvqVector { k: i32::MAX as u32, components: vec![i32::MAX], rho: 1.0 },
        PvqVector { k: 1u32 << 31, components: vec![i32::MIN], rho: 0.5 },
        // extremes mixed with ordinary values and zeros
        PvqVector {
            k: (1u32 << 31) + 4,
            components: vec![0, i32::MIN, 0, 2, -1, 1, 0],
            rho: 0.25,
        },
        // Σ|c| = (2^31 − 1) + 2^31 = u32::MAX: the largest legal K
        PvqVector { k: u32::MAX, components: vec![i32::MAX, 0, i32::MIN, 0], rho: 2.0 },
    ];
    for q in &boundary_layers {
        assert!(q.is_valid(), "test vector must satisfy Σ|c| = K: {q:?}");
        for codec in Codec::ALL {
            let bytes = compress_layer(q, codec);
            let back = decompress_layer(&bytes)
                .unwrap_or_else(|e| panic!("{codec:?} on {q:?}: {e}"));
            assert_eq!(back.components, q.components, "{codec:?}");
            assert_eq!(back.k, q.k, "{codec:?}");
        }
    }
}

#[test]
fn null_vector_and_degenerate_shapes_roundtrip() {
    for q in [
        // K = 0 encodes the null vector (rho 0): legal per the spec
        PvqVector { k: 0, components: vec![0; 32], rho: 0.0 },
        PvqVector { k: 0, components: vec![], rho: 0.0 },
        // single-pulse layers
        PvqVector { k: 1, components: vec![-1], rho: 3.5 },
        PvqVector { k: 1, components: vec![0, 0, 1, 0], rho: 0.125 },
    ] {
        for codec in Codec::ALL {
            let bytes = compress_layer(&q, codec);
            let back = decompress_layer(&bytes).unwrap();
            assert_eq!(back.components, q.components, "{codec:?} {q:?}");
        }
    }
}

#[test]
fn mutated_containers_error_never_panic() {
    check("layer container mutation safety", 0xDEAD, 30, |_, rng| {
        let n = 16 + rng.below(200) as usize;
        let ratio = [1usize, 2, 5][rng.below(3) as usize];
        let q = sample_layer(rng, n, ratio);
        let codec = Codec::ALL[rng.below(Codec::ALL.len() as u64) as usize];
        let mut bytes = compress_layer(&q, codec);
        match rng.below(3) {
            // single byte flip anywhere (header, freq table, payload)
            0 => {
                let at = rng.below(bytes.len() as u64) as usize;
                bytes[at] ^= 1 << rng.below(8);
            }
            // truncation
            1 => bytes.truncate(rng.below(bytes.len() as u64) as usize),
            // garbage tail
            _ => bytes.extend((0..rng.below(16)).map(|_| rng.below(256) as u8)),
        }
        // Ok or Err, never a panic; a mutation that survives decode
        // must still yield a valid pyramid point (Σ|c| = K is the
        // decoder's last gate)
        if let Ok(back) = decompress_layer(&bytes) {
            assert!(back.is_valid() || back.k == 0);
        }
        // the streamed decode_into path must be exactly as corruption-
        // safe as the dense path: Ok with a valid pulse sum, or Err
        let mut sink = RecordingSink::default();
        if decompress_layer_into(&bytes, &mut sink).is_ok() {
            assert!(sink.l1 == sink.k as u64 || sink.k == 0);
        }
    });
}

/// PulseSink that rebuilds the dense vector and records stream order.
#[derive(Default)]
struct RecordingSink {
    n: usize,
    k: u32,
    rho: f64,
    dense: Vec<i32>,
    l1: u64,
    last_pos: Option<usize>,
    ordered: bool,
}

impl PulseSink for RecordingSink {
    fn begin(&mut self, n: usize, k: u32, rho: f64) {
        self.n = n;
        self.k = k;
        self.rho = rho;
        self.dense = vec![0; n];
        self.l1 = 0;
        self.last_pos = None;
        self.ordered = true;
    }
    fn pulse(&mut self, pos: usize, mag: u32, neg: bool) {
        if self.last_pos.is_some_and(|p| pos <= p) {
            self.ordered = false;
        }
        self.last_pos = Some(pos);
        self.dense[pos] = if neg { -(mag as i64) as i32 } else { mag as i32 };
        self.l1 += mag as u64;
    }
}

#[test]
fn streamed_decode_matches_dense_decode_for_every_codec() {
    // decode_into is the serving load path; it must reproduce exactly
    // what dense decode-then-scan produces, for every codec (CWRS
    // streams natively, the others replay their dense decode), with
    // strictly increasing positions — the contract the CSR and binary
    // compilers rely on.
    check("decode_into ≡ dense decode", 0x51D3, 4, |_, rng| {
        for n in [1usize, 63, 300] {
            for ratio in [1usize, 3, 8] {
                let q = sample_layer(rng, n, ratio);
                for codec in Codec::ALL {
                    let bytes = compress_layer(&q, codec);
                    let mut sink = RecordingSink::default();
                    decompress_layer_into(&bytes, &mut sink)
                        .unwrap_or_else(|e| panic!("{codec:?} N={n} N/K={ratio}: {e}"));
                    assert!(sink.ordered, "{codec:?}: positions must strictly increase");
                    assert_eq!(sink.dense, q.components, "{codec:?} N={n} N/K={ratio}");
                    assert_eq!(sink.k, q.k, "{codec:?}");
                    assert_eq!(sink.rho.to_bits(), q.rho.to_bits(), "{codec:?}");
                }
            }
        }
    });
}

#[test]
fn streamed_decode_handles_i32_boundary_magnitudes() {
    // CWRS falls back to zigzag exp-Golomb groups when Σ|c| exceeds its
    // count-table cap; the boundary magnitudes must stream through
    // decode_into exactly (the sink sees magnitude 2^31 as u32)
    let q = PvqVector {
        k: u32::MAX,
        components: vec![i32::MAX, 0, i32::MIN, 0],
        rho: 2.0,
    };
    for codec in Codec::ALL {
        let bytes = compress_layer(&q, codec);
        let mut sink = RecordingSink::default();
        decompress_layer_into(&bytes, &mut sink).unwrap();
        assert_eq!(sink.dense, q.components, "{codec:?}");
        assert_eq!(sink.l1, u32::MAX as u64, "{codec:?}");
    }
}

/// Hand-build a PVQL container around a raw RLE payload.
fn rle_container(n: u32, k: u32, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(b"PVQL");
    out.push(Codec::Rle.id());
    out.extend_from_slice(&n.to_le_bytes());
    out.extend_from_slice(&k.to_le_bytes());
    out.extend_from_slice(&1.0f64.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

#[test]
fn crafted_rle_payloads_are_rejected_not_panics() {
    use pvqnet::compress::bitio::BitWriter;

    // a zero-run near u64::MAX used to overflow `out.len() + run` in
    // the decoder (debug panic); all-zero bits decode as a huge ue
    let mut zeros = vec![0u8; 20];
    zeros.push(0xFF);
    assert!(decompress_layer(&rle_container(4, 2, &zeros)).is_err());

    // a packed nonzero of i64::MAX used to overflow `p + 1` before the
    // old `as i32` truncation even ran
    let mut w = BitWriter::new();
    pvqnet::compress::expgolomb::write_ue(&mut w, 0); // run 0
    pvqnet::compress::expgolomb::write_ue(&mut w, u64::MAX - 2); // p = i64::MAX
    pvqnet::compress::expgolomb::write_ue(&mut w, 0); // tail
    let payload = w.finish();
    assert!(decompress_layer(&rle_container(1, 1, &payload)).is_err());
}
