//! End-to-end socket tests for the HTTP/1.1 serving front end
//! (`coordinator::http`): raw loopback TCP clients against a live
//! `HttpServer`, verifying classify correctness against a direct
//! registry, every error-path status code, admission-control `429`s,
//! concurrent keep-alive connections, and graceful shutdown that
//! answers (never strands) in-flight requests. Loopback sockets only —
//! no external network.

use pvqnet::coordinator::{EngineKind, HttpConfig, HttpServer, ModelRegistry, ServerConfig};
use pvqnet::nn::model::{Activation, LayerSpec, ModelSpec};
use pvqnet::nn::{Model, QuantModel};
use pvqnet::pvq::RhoMode;
use pvqnet::quant::quantize;
use pvqnet::testkit::Rng;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

const INPUT: usize = 16;

fn quant_mlp(seed: u64) -> QuantModel {
    let spec = ModelSpec {
        name: "e2e".into(),
        input_shape: vec![INPUT],
        layers: vec![
            LayerSpec::Dense { input: INPUT, output: 8, act: Activation::Relu },
            LayerSpec::Dense { input: 8, output: 4, act: Activation::None },
        ],
    };
    let m = Model::synth(&spec, seed);
    quantize(&m, &[1.5, 1.0], RhoMode::Norm).unwrap().quant_model
}

fn registry(seed: u64) -> ModelRegistry {
    let mut reg = ModelRegistry::new(ServerConfig::default());
    reg.register_quant("m", quant_mlp(seed), EngineKind::Auto, None).unwrap();
    reg
}

fn start(seed: u64, cfg: HttpConfig) -> HttpServer {
    HttpServer::start(registry(seed), cfg, "127.0.0.1:0").unwrap()
}

fn random_pixels(rng: &mut Rng) -> Vec<u8> {
    (0..INPUT).map(|_| rng.below(256) as u8).collect()
}

fn pixels_json(p: &[u8]) -> String {
    let nums: Vec<String> = p.iter().map(|v| v.to_string()).collect();
    format!("[{}]", nums.join(","))
}

/// Minimal keep-alive HTTP client: sends requests and reads exactly one
/// `Content-Length`-framed response per call.
struct Client {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        Client { stream, buf: Vec::new() }
    }

    fn send(&mut self, raw: &str) {
        self.stream.write_all(raw.as_bytes()).unwrap();
        self.stream.flush().unwrap();
    }

    /// Read one response. `Err(true)` means the connection died *mid*
    /// response (a half-written answer — always a bug), `Err(false)` a
    /// clean close before any response byte (e.g. server drained).
    fn try_read_response(&mut self) -> Result<(u16, String, String), bool> {
        let mut got_bytes = !self.buf.is_empty();
        let head_end = loop {
            if let Some(i) = self.buf.windows(4).position(|w| w == b"\r\n\r\n") {
                break i;
            }
            let mut chunk = [0u8; 4096];
            match self.stream.read(&mut chunk) {
                Ok(0) | Err(_) => return Err(got_bytes),
                Ok(n) => {
                    got_bytes = true;
                    self.buf.extend_from_slice(&chunk[..n]);
                }
            }
        };
        let head = String::from_utf8(self.buf[..head_end].to_vec()).unwrap();
        let status: u16 = head
            .split(' ')
            .nth(1)
            .expect("status code in status line")
            .parse()
            .expect("numeric status");
        let content_len: usize = head
            .lines()
            .find_map(|l| {
                let (name, v) = l.split_once(':')?;
                name.eq_ignore_ascii_case("content-length").then(|| v.trim().parse().unwrap())
            })
            .expect("Content-Length header");
        let body_start = head_end + 4;
        while self.buf.len() < body_start + content_len {
            let mut chunk = [0u8; 4096];
            match self.stream.read(&mut chunk) {
                Ok(0) | Err(_) => return Err(true),
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
            }
        }
        let rest = self.buf.split_off(body_start + content_len);
        let body = String::from_utf8(self.buf[body_start..].to_vec()).unwrap();
        self.buf = rest;
        Ok((status, head, body))
    }

    /// Read one response; panics if the connection closes instead.
    fn read_response(&mut self) -> (u16, String, String) {
        self.try_read_response().expect("complete response before close")
    }

    fn post_classify(&mut self, body: &str, keep_alive: bool) -> (u16, String, String) {
        let conn = if keep_alive { "keep-alive" } else { "close" };
        let raw = format!(
            "POST /v1/classify HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: {conn}\r\n\r\n{body}",
            body.len()
        );
        self.send(&raw);
        self.read_response()
    }

    fn get(&mut self, path: &str) -> (u16, String, String) {
        let raw = format!("GET {path} HTTP/1.1\r\nHost: t\r\nConnection: keep-alive\r\n\r\n");
        self.send(&raw);
        self.read_response()
    }
}

/// Pull `"class":N` values out of a response body in order.
fn classes_in(body: &str) -> Vec<usize> {
    body.match_indices("\"class\":")
        .map(|(i, pat)| {
            let digits: String = body[i + pat.len()..]
                .chars()
                .take_while(|c| c.is_ascii_digit())
                .collect();
            digits.parse().unwrap()
        })
        .collect()
}

#[test]
fn classify_roundtrip_matches_direct_registry() {
    // same seed → same quantized model on both sides of the wire
    let direct = registry(41);
    let server = start(41, HttpConfig::default());
    let mut client = Client::connect(server.addr());
    let mut rng = Rng::new(7);

    // single-sample bodies, once routed by name and once by default
    for model_field in ["", "\"model\":\"m\","] {
        let p = random_pixels(&mut rng);
        let want = direct.classify(None, p.clone()).unwrap().class;
        let body = format!("{{{model_field}\"pixels\":{}}}", pixels_json(&p));
        let (status, _, resp) = client.post_classify(&body, true);
        assert_eq!(status, 200, "{resp}");
        assert_eq!(classes_in(&resp), vec![want], "{resp}");
        assert!(resp.contains("\"model\":\"m\""));
        assert!(resp.contains("\"latency_us\":"));
    }

    // batch body answers in request order
    let samples: Vec<Vec<u8>> = (0..9).map(|_| random_pixels(&mut rng)).collect();
    let want: Vec<usize> = direct
        .classify_batch(None, samples.clone())
        .unwrap()
        .iter()
        .map(|r| r.class)
        .collect();
    let rows: Vec<String> = samples.iter().map(|p| pixels_json(p)).collect();
    let body = format!("{{\"samples\":[{}]}}", rows.join(","));
    let (status, _, resp) = client.post_classify(&body, false);
    assert_eq!(status, 200, "{resp}");
    assert_eq!(classes_in(&resp), want, "{resp}");

    // the front end counted what it admitted
    assert_eq!(server.metrics().http_admitted.load(std::sync::atomic::Ordering::Relaxed), 3);
    direct.shutdown();
    server.shutdown();
}

#[test]
fn error_status_codes() {
    let server = start(43, HttpConfig { max_body_bytes: 4096, ..Default::default() });
    let mut c = Client::connect(server.addr());
    let ok_pixels = pixels_json(&vec![0u8; INPUT]);

    // unknown route
    let (status, _, _) = c.get("/v1/nope");
    assert_eq!(status, 404);
    // wrong method on a known route
    c.send("DELETE /metrics HTTP/1.1\r\nHost: t\r\nConnection: keep-alive\r\n\r\n");
    let (status, _, _) = c.read_response();
    assert_eq!(status, 405);
    // malformed JSON
    let (status, _, body) = c.post_classify("{\"pixels\":[1,", true);
    assert_eq!(status, 400, "{body}");
    // neither pixels nor samples
    let (status, _, _) = c.post_classify("{\"x\":1}", true);
    assert_eq!(status, 400);
    // non-pixel values
    let (status, _, _) = c.post_classify("{\"pixels\":[1,2,999]}", true);
    assert_eq!(status, 400);
    // wrong pixel count
    let (status, _, body) = c.post_classify("{\"pixels\":[1,2,3]}", true);
    assert_eq!(status, 400);
    assert!(body.contains("expects 16 pixels"), "{body}");
    // unknown model name
    let body = format!("{{\"model\":\"ghost\",\"pixels\":{ok_pixels}}}");
    let (status, _, resp) = c.post_classify(&body, true);
    assert_eq!(status, 404, "{resp}");
    // oversized declared body → 413 and the connection closes
    let (status, _, _) = c.post_classify(&format!("{{\"pixels\":[{}]}}", "0,".repeat(4000)), true);
    assert_eq!(status, 413);

    let m = server.metrics();
    assert!(m.http_errors.load(std::sync::atomic::Ordering::Relaxed) >= 8);
    server.shutdown();
}

#[test]
fn saturation_answers_429_with_retry_after() {
    // max_inflight 0: every classify is over budget — the deterministic
    // stand-in for "the batching queue is saturated"; the request is
    // answered immediately, never hung or dropped
    let server = start(45, HttpConfig { max_inflight: 0, ..Default::default() });
    let mut c = Client::connect(server.addr());
    let body = format!("{{\"pixels\":{}}}", pixels_json(&vec![1u8; INPUT]));
    for _ in 0..3 {
        let (status, head, _) = c.post_classify(&body, true);
        assert_eq!(status, 429);
        assert!(head.contains("Retry-After: 1"), "{head}");
    }
    // health and metrics still answer while classify is saturated
    let (status, _, body) = c.get("/healthz");
    assert_eq!(status, 200);
    assert!(body.contains("\"ok\""));
    let (status, _, body) = c.get("/metrics");
    assert_eq!(status, 200);
    assert!(body.contains("pvqnet_http_rejected_total 3"), "{body}");
    server.shutdown();
}

#[test]
fn concurrent_keepalive_connections() {
    let direct = registry(47);
    // one connection worker per client so all 8 keep-alive connections
    // are genuinely served concurrently
    let server = start(47, HttpConfig { conn_workers: 8, ..Default::default() });
    let addr = server.addr();
    let clients: u64 = 8;
    let per_client: u64 = 10;
    let mut handles = Vec::new();
    for ci in 0..clients {
        let direct_want: Vec<(Vec<u8>, usize)> = {
            let mut rng = Rng::new(100 + ci);
            (0..per_client)
                .map(|_| {
                    let p = random_pixels(&mut rng);
                    let want = direct.classify(None, p.clone()).unwrap().class;
                    (p, want)
                })
                .collect()
        };
        handles.push(std::thread::spawn(move || {
            // one persistent connection per client, requests in series
            let mut c = Client::connect(addr);
            for (p, want) in direct_want {
                let body = format!("{{\"pixels\":{}}}", pixels_json(&p));
                let (status, _, resp) = c.post_classify(&body, true);
                assert_eq!(status, 200, "{resp}");
                assert_eq!(classes_in(&resp), vec![want], "{resp}");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = server.metrics();
    let admitted = m.http_admitted.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(admitted, clients * per_client);
    direct.shutdown();
    server.shutdown();
}

#[test]
fn graceful_shutdown_answers_every_inflight_request() {
    let server = start(49, HttpConfig::default());
    let addr = server.addr();
    let mut handles = Vec::new();
    for ci in 0..4 {
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(200 + ci);
            let mut c = Client::connect(addr);
            let mut outcomes = Vec::new();
            loop {
                let body = format!("{{\"pixels\":{}}}", pixels_json(&random_pixels(&mut rng)));
                let raw = format!(
                    "POST /v1/classify HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
                     Connection: keep-alive\r\n\r\n{body}",
                    body.len()
                );
                // once the listener dies mid-drain the write or read
                // errors — that is the loop's clean exit; what must
                // never happen is a hang or a half-written response
                if c.stream.write_all(raw.as_bytes()).is_err() {
                    break;
                }
                match c.try_read_response() {
                    Ok((s, _, _)) => outcomes.push(s),
                    // clean close between responses: explicit end
                    Err(false) => break,
                    Err(true) => panic!("connection died mid-response during drain"),
                }
            }
            outcomes
        }));
    }
    // let the clients get some requests in flight, then drain
    std::thread::sleep(Duration::from_millis(150));
    server.shutdown();
    let mut total = 0usize;
    for h in handles {
        let outcomes = h.join().expect("client thread must terminate after drain");
        for &s in &outcomes {
            // every completed exchange carries a definitive status:
            // success, or an explicit drain/saturation answer
            assert!(matches!(s, 200 | 429 | 503), "unexpected status {s}");
        }
        total += outcomes.len();
    }
    assert!(total > 0, "shutdown raced ahead of every client");
}
