//! End-to-end socket tests for the HTTP/1.1 serving front end
//! (`coordinator::http`): raw loopback TCP clients against a live
//! `HttpServer`, verifying classify correctness against a direct
//! registry, every error-path status code, admission-control `429`s,
//! concurrent keep-alive connections, and graceful shutdown that
//! answers (never strands) in-flight requests. The client plumbing
//! (Content-Length-framed reader, classify body shaping) lives in
//! `pvqnet::testkit::http`, shared with the bench harness and the
//! `loadgen` subsystem. Loopback sockets only — no external network.

use pvqnet::coordinator::{
    Classify, ClassifyRequest, EngineKind, HttpConfig, HttpServer, ModelRegistry, ServerConfig,
};
use pvqnet::nn::model::{Activation, LayerSpec, ModelSpec};
use pvqnet::nn::{Model, QuantModel};
use pvqnet::pvq::RhoMode;
use pvqnet::quant::quantize;
use pvqnet::testkit::http::{classes_in, pixels_json, HttpTestClient, RecvFailure};
use pvqnet::testkit::Rng;
use std::io::Write;
use std::time::Duration;

const INPUT: usize = 16;

fn quant_mlp(seed: u64) -> QuantModel {
    let spec = ModelSpec {
        name: "e2e".into(),
        input_shape: vec![INPUT],
        layers: vec![
            LayerSpec::Dense { input: INPUT, output: 8, act: Activation::Relu },
            LayerSpec::Dense { input: 8, output: 4, act: Activation::None },
        ],
    };
    let m = Model::synth(&spec, seed);
    quantize(&m, &[1.5, 1.0], RhoMode::Norm).unwrap().quant_model
}

fn registry(seed: u64) -> ModelRegistry {
    let mut reg = ModelRegistry::new(ServerConfig::default());
    reg.register_quant("m", quant_mlp(seed), EngineKind::Auto, None).unwrap();
    reg
}

fn start(seed: u64, cfg: HttpConfig) -> HttpServer {
    HttpServer::start(registry(seed), cfg, "127.0.0.1:0").unwrap()
}

fn random_pixels(rng: &mut Rng) -> Vec<u8> {
    (0..INPUT).map(|_| rng.below(256) as u8).collect()
}

#[test]
fn classify_roundtrip_matches_direct_registry() {
    // same seed → same quantized model on both sides of the wire
    let direct = registry(41);
    let server = start(41, HttpConfig::default());
    let mut client = HttpTestClient::connect(server.addr()).unwrap();
    let mut rng = Rng::new(7);

    // single-sample bodies, once routed by name and once by default
    for model_field in ["", "\"model\":\"m\","] {
        let p = random_pixels(&mut rng);
        let want = direct.submit(ClassifyRequest::single(p.clone())).unwrap().results[0].class;
        let body = format!("{{{model_field}\"pixels\":{}}}", pixels_json(&p));
        let resp = client.post_classify(&body, true);
        assert_eq!(resp.status, 200, "{}", resp.body);
        assert_eq!(classes_in(&resp.body), vec![want], "{}", resp.body);
        assert!(resp.body.contains("\"model\":\"m\""));
        assert!(resp.body.contains("\"latency_us\":"));
    }

    // batch body answers in request order
    let samples: Vec<Vec<u8>> = (0..9).map(|_| random_pixels(&mut rng)).collect();
    let want: Vec<usize> = direct
        .submit(ClassifyRequest::batch(samples.clone()))
        .unwrap()
        .results
        .iter()
        .map(|r| r.class)
        .collect();
    let rows: Vec<String> = samples.iter().map(|p| pixels_json(p)).collect();
    let body = format!("{{\"samples\":[{}]}}", rows.join(","));
    let resp = client.post_classify(&body, false);
    assert_eq!(resp.status, 200, "{}", resp.body);
    assert_eq!(classes_in(&resp.body), want, "{}", resp.body);

    // the front end counted what it admitted
    assert_eq!(server.metrics().http_admitted.load(std::sync::atomic::Ordering::Relaxed), 3);
    direct.shutdown();
    server.shutdown();
}

#[test]
fn error_status_codes() {
    let server = start(43, HttpConfig { max_body_bytes: 4096, ..Default::default() });
    let mut c = HttpTestClient::connect(server.addr()).unwrap();
    let ok_pixels = pixels_json(&vec![0u8; INPUT]);

    // unknown route
    assert_eq!(c.get("/v1/nope").status, 404);
    // wrong method on a known route
    c.send(b"DELETE /metrics HTTP/1.1\r\nHost: t\r\nConnection: keep-alive\r\n\r\n")
        .unwrap();
    assert_eq!(c.read_response().status, 405);
    // malformed JSON
    let resp = c.post_classify("{\"pixels\":[1,", true);
    assert_eq!(resp.status, 400, "{}", resp.body);
    // neither pixels nor samples
    assert_eq!(c.post_classify("{\"x\":1}", true).status, 400);
    // non-pixel values
    assert_eq!(c.post_classify("{\"pixels\":[1,2,999]}", true).status, 400);
    // wrong pixel count
    let resp = c.post_classify("{\"pixels\":[1,2,3]}", true);
    assert_eq!(resp.status, 400);
    assert!(resp.body.contains("expects 16 pixels"), "{}", resp.body);
    // unknown model name
    let body = format!("{{\"model\":\"ghost\",\"pixels\":{ok_pixels}}}");
    let resp = c.post_classify(&body, true);
    assert_eq!(resp.status, 404, "{}", resp.body);
    // oversized declared body → 413 and the connection closes
    let resp = c.post_classify(&format!("{{\"pixels\":[{}]}}", "0,".repeat(4000)), true);
    assert_eq!(resp.status, 413);
    assert!(resp.connection_close());

    let m = server.metrics();
    assert!(m.http_errors.load(std::sync::atomic::Ordering::Relaxed) >= 8);
    server.shutdown();
}

#[test]
fn slow_request_times_out_with_408() {
    // the injectable read deadline (HttpConfig::read_deadline → the
    // event loop's deadline wheel) turns a wedged-slow client into a
    // fast explicit 408
    let server = start(
        53,
        HttpConfig { read_deadline: Duration::from_millis(150), ..Default::default() },
    );
    let mut c = HttpTestClient::connect(server.addr()).unwrap();
    let body = format!("{{\"pixels\":{}}}", pixels_json(&vec![3u8; INPUT]));
    let head = format!(
        "POST /v1/classify HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
        body.len()
    );
    c.send(head.as_bytes()).unwrap();
    // dribble a few body bytes, then stall past the deadline
    c.send(&body.as_bytes()[..4]).unwrap();
    std::thread::sleep(Duration::from_millis(400));
    let resp = match c.try_read_response() {
        Ok(r) => r,
        Err(e) => panic!("expected an explicit 408, connection just {e:?}"),
    };
    assert_eq!(resp.status, 408, "{}", resp.body);
    server.shutdown();
}

#[test]
fn saturation_answers_429_with_retry_after() {
    // max_inflight 0: every classify is over budget — the deterministic
    // stand-in for "the batching queue is saturated"; the request is
    // answered immediately, never hung or dropped
    let server = start(45, HttpConfig { max_inflight: 0, ..Default::default() });
    let mut c = HttpTestClient::connect(server.addr()).unwrap();
    let body = format!("{{\"pixels\":{}}}", pixels_json(&vec![1u8; INPUT]));
    for _ in 0..3 {
        let resp = c.post_classify(&body, true);
        assert_eq!(resp.status, 429);
        assert!(resp.head.contains("Retry-After: 1"), "{}", resp.head);
    }
    // health and metrics still answer while classify is saturated
    let resp = c.get("/healthz");
    assert_eq!(resp.status, 200);
    assert!(resp.body.contains("\"ok\""));
    let resp = c.get("/metrics");
    assert_eq!(resp.status, 200);
    assert!(resp.body.contains("pvqnet_http_rejected_total 3"), "{}", resp.body);
    server.shutdown();
}

#[test]
fn concurrent_keepalive_connections() {
    let direct = registry(47);
    // the epoll loops multiplex all 8 keep-alive connections without a
    // per-connection worker
    let server = start(47, HttpConfig::default());
    let addr = server.addr();
    let clients: u64 = 8;
    let per_client: u64 = 10;
    let mut handles = Vec::new();
    for ci in 0..clients {
        let direct_want: Vec<(Vec<u8>, usize)> = {
            let mut rng = Rng::new(100 + ci);
            (0..per_client)
                .map(|_| {
                    let p = random_pixels(&mut rng);
                    let want =
                        direct.submit(ClassifyRequest::single(p.clone())).unwrap().results[0]
                            .class;
                    (p, want)
                })
                .collect()
        };
        handles.push(std::thread::spawn(move || {
            // one persistent connection per client, requests in series
            let mut c = HttpTestClient::connect(addr).unwrap();
            for (p, want) in direct_want {
                let body = format!("{{\"pixels\":{}}}", pixels_json(&p));
                let resp = c.post_classify(&body, true);
                assert_eq!(resp.status, 200, "{}", resp.body);
                assert_eq!(classes_in(&resp.body), vec![want], "{}", resp.body);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = server.metrics();
    let admitted = m.http_admitted.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(admitted, clients * per_client);
    direct.shutdown();
    server.shutdown();
}

#[test]
fn graceful_shutdown_answers_every_inflight_request() {
    let server = start(49, HttpConfig::default());
    let addr = server.addr();
    let mut handles = Vec::new();
    for ci in 0..4 {
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(200 + ci);
            let mut c = HttpTestClient::connect(addr).unwrap();
            let mut outcomes = Vec::new();
            loop {
                let body = format!("{{\"pixels\":{}}}", pixels_json(&random_pixels(&mut rng)));
                let raw = format!(
                    "POST /v1/classify HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\
                     Connection: keep-alive\r\n\r\n{body}",
                    body.len()
                );
                // once the listener dies mid-drain the write or read
                // errors — that is the loop's clean exit; what must
                // never happen is a hang or a half-written response
                if c.stream.write_all(raw.as_bytes()).is_err() {
                    break;
                }
                match c.try_read_response() {
                    Ok(r) => outcomes.push(r.status),
                    // clean close between responses: explicit end
                    Err(RecvFailure::Closed) => break,
                    Err(RecvFailure::MidResponse) => {
                        panic!("connection died mid-response during drain")
                    }
                    Err(RecvFailure::TimedOut) => {
                        panic!("request swallowed without an answer during drain")
                    }
                }
            }
            outcomes
        }));
    }
    // let the clients get some requests in flight, then drain
    std::thread::sleep(Duration::from_millis(150));
    server.shutdown();
    let mut total = 0usize;
    for h in handles {
        let outcomes = h.join().expect("client thread must terminate after drain");
        for &s in &outcomes {
            // every completed exchange carries a definitive status:
            // success, or an explicit drain/saturation answer
            assert!(matches!(s, 200 | 429 | 503), "unexpected status {s}");
        }
        total += outcomes.len();
    }
    assert!(total > 0, "shutdown raced ahead of every client");
}

#[test]
fn four_thousand_concurrent_keepalive_clients_with_faults() {
    // the headline scaling claim of the event-driven front end: 4096
    // simultaneously open keep-alive connections (well past any
    // worker-pool size), driven closed-loop through the seeded loadgen
    // harness with the full wire-fault schedule — slow clients,
    // mid-body disconnects, corrupt/truncated/oversized bodies, model
    // misses. Every one of the 8192 requests must end in an explicit
    // outcome (zero Unanswered) and every 200 must verify bitwise
    // against the direct engines. Tracing stays off: 8192×8 spans
    // would wrap the bounded span rings and fail the chain check
    // spuriously (chain completeness is gated in loadgen_e2e at a
    // ring-sized scale).
    use pvqnet::loadgen::{run, LoadConfig, TrafficShape};
    let cfg = LoadConfig {
        seed: 4096,
        requests: 8192,
        shape: TrafficShape::Closed { clients: 4096 },
        drive_http: true,
        drive_inproc: false,
        fault_every: 6,
        drain_after: None,
        server: ServerConfig::default(),
        http: HttpConfig::default(),
        read_timeout: Duration::from_secs(60),
        model_seed: 42,
        trace: false,
    };
    let report = run(&cfg).unwrap();
    let http = report.http.as_ref().expect("http path driven");
    assert_eq!(http.sent as usize, http.planned, "every request attempted");
    assert_eq!(http.accounted(), http.sent, "outcome buckets must sum to sent");
    assert_eq!(http.unanswered, 0, "swallowed requests under 4096-conn load");
    assert_eq!(http.oracle_mismatches, 0, "{:?}", http.mismatch_examples);
    assert!(http.oracle_checked > 0, "oracle never ran");
    // the wire faults actually ran at scale
    assert!(http.fault_answered > 0, "no injected fault was answered");
    assert!(http.aborted > 0, "disconnect-mid-body faults never aborted");
    assert!(report.passed(), "{}", report.render());
}
