//! Statistics-core unit tests against hand-computed fixtures: Welford
//! moments, Student-t CIs, Welch's t-test on textbook-style cases, and
//! the degenerate-input contract (n = 1, zero variance, empty inputs
//! surface as explicit "insufficient data", never as NaN verdicts).

use pvqnet::bench::{
    t_crit_95, tukey_filter, welch_t_test, Measurement, Protocol, StatError, Summary, Welford,
};

// ------------------------------------------------------- moments and CIs

#[test]
fn welford_matches_hand_computed_moments() {
    // the classic Welford example: mean 5, sample variance 32/7
    let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
    let mut w = Welford::new();
    for &x in &xs {
        w.push(x);
    }
    let s = w.summary().unwrap();
    assert_eq!(s.n, 8);
    assert!((s.mean - 5.0).abs() < 1e-12);
    assert!((s.std * s.std - 32.0 / 7.0).abs() < 1e-12);
    assert_eq!((s.min, s.max), (2.0, 9.0));
    // single-pass == slice constructor
    assert_eq!(Summary::from_samples(&xs).unwrap(), s);
}

#[test]
fn ci95_on_a_known_sample() {
    // xs = 10,12,14,16,18: mean 14, sample std √10, sem √2,
    // t(df=4) = 2.776 → half-width 2.776·√2
    let s = Summary::from_samples(&[10.0, 12.0, 14.0, 16.0, 18.0]).unwrap();
    assert!((s.mean - 14.0).abs() < 1e-12);
    assert!((s.std - 10f64.sqrt()).abs() < 1e-12);
    assert!((s.sem().unwrap() - 2f64.sqrt()).abs() < 1e-12);
    let ci = s.ci95_half().unwrap();
    assert!((ci - 2.776 * 2f64.sqrt()).abs() < 1e-9, "ci {ci}");
}

#[test]
fn t_table_lookup_and_interpolation() {
    assert!((t_crit_95(1.0) - 12.706).abs() < 1e-9);
    assert!((t_crit_95(4.0) - 2.776).abs() < 1e-9);
    assert!((t_crit_95(25.0) - 2.060).abs() < 1e-9);
    // fractional df (Welch–Satterthwaite) interpolates between rows
    assert!((t_crit_95(2.5) - (4.303 + 3.182) / 2.0).abs() < 1e-9);
    // large df decays to the two-sided normal limit
    assert!((t_crit_95(1e12) - 1.960).abs() < 1e-6);
    assert!(t_crit_95(f64::INFINITY) == 1.960);
    // monotone non-increasing over a sweep
    let mut prev = f64::INFINITY;
    for df in 1..300 {
        let t = t_crit_95(df as f64);
        assert!(t <= prev + 1e-12, "t_crit not monotone at df {df}");
        prev = t;
    }
}

// --------------------------------------------------------------- Welch

#[test]
fn welch_equal_means_is_no_regression() {
    let a = Summary { n: 20, mean: 1000.0, std: 10.0, min: 0.0, max: 0.0 };
    let w = welch_t_test(&a, &a).unwrap();
    assert_eq!(w.t, 0.0);
    assert!(!w.significant, "identical summaries must not flag");
}

#[test]
fn welch_shifted_means_textbook_case() {
    // equal n and std: se² = 2·(10²/20) = 10, t = 100/√10 ≈ 31.62,
    // Welch–Satterthwaite df = 2(n−1) = 38 exactly
    let a = Summary { n: 20, mean: 1000.0, std: 10.0, min: 0.0, max: 0.0 };
    let b = Summary { n: 20, mean: 1100.0, std: 10.0, min: 0.0, max: 0.0 };
    let w = welch_t_test(&a, &b).unwrap();
    assert!((w.t - 100.0 / 10f64.sqrt()).abs() < 1e-9, "t {}", w.t);
    assert!((w.df - 38.0).abs() < 1e-9, "df {}", w.df);
    assert!(w.significant);
    // direction is signed: swapping the sides flips t
    let back = welch_t_test(&b, &a).unwrap();
    assert!((back.t + w.t).abs() < 1e-12);
}

#[test]
fn welch_unequal_variances_unequal_n() {
    // a: n=15 mean 20 std 2 (va = 4/15); b: n=10 mean 22 std 5
    // (vb = 2.5): t = 2/√2.7667 ≈ 1.202, df ≈ 10.94 — a small shift
    // under big variance is NOT significant
    let a = Summary { n: 15, mean: 20.0, std: 2.0, min: 0.0, max: 0.0 };
    let b = Summary { n: 10, mean: 22.0, std: 5.0, min: 0.0, max: 0.0 };
    let w = welch_t_test(&a, &b).unwrap();
    assert!(w.t > 1.20 && w.t < 1.21, "t {}", w.t);
    assert!(w.df > 10.9 && w.df < 11.0, "df {}", w.df);
    assert!(!w.significant, "t {} vs crit {}", w.t, w.t_crit);
    // the same shift with tight variance IS significant
    let tight = Summary { n: 10, mean: 22.0, std: 0.5, min: 0.0, max: 0.0 };
    assert!(welch_t_test(&a, &tight).unwrap().significant);
}

// ----------------------------------------------- degenerate inputs

#[test]
fn degenerate_inputs_are_explicit_not_nan() {
    let one = Summary { n: 1, mean: 5.0, std: 0.0, min: 5.0, max: 5.0 };
    let many = Summary { n: 20, mean: 5.0, std: 1.0, min: 0.0, max: 0.0 };
    // n = 1 on either side
    assert!(matches!(welch_t_test(&one, &many), Err(StatError::TooFewSamples)));
    assert!(matches!(welch_t_test(&many, &one), Err(StatError::TooFewSamples)));
    // zero variance on both sides
    let flat = Summary { n: 20, mean: 5.0, std: 0.0, min: 5.0, max: 5.0 };
    assert!(matches!(welch_t_test(&flat, &flat), Err(StatError::ZeroVariance)));
    // the messages say "insufficient data", the words the verdict
    // table renders instead of a NaN
    assert_eq!(StatError::TooFewSamples.to_string(), "insufficient data (fewer than 2 samples)");
    assert_eq!(StatError::ZeroVariance.to_string(), "insufficient data (zero variance)");
    // empty sample sets never produce a summary at all
    assert!(Summary::from_samples(&[]).is_none());
    assert!(Welford::new().summary().is_none());
    assert_eq!(Welford::new().mean(), 0.0);
    assert!(Welford::new().sample_variance().is_none());
    // n = 1 has a mean but no variance/sem/CI
    let s = Summary::from_samples(&[7.5]).unwrap();
    assert_eq!((s.n, s.mean), (1, 7.5));
    assert!(s.sem().is_none());
    assert!(s.ci95_half().is_none());
    // none of the paths above manufactured a NaN
    assert!(!s.mean.is_nan() && !s.std.is_nan());
}

#[test]
fn single_iteration_measurement_reports_no_ci() {
    let m = Measurement::from_values(vec![3.25], 0);
    assert_eq!(m.n(), 1);
    assert_eq!(m.mean(), 3.25);
    assert_eq!(m.ci95(), 0.0, "n=1: zero half-width, n tells the story");
    // and the smoke protocol is exactly that shape
    let m = Protocol::SMOKE.run(|| 9.0);
    assert_eq!((m.n(), m.warmup), (1, 0));
}

// ---------------------------------------------------------- outliers

#[test]
fn tukey_fences_drop_only_outliers() {
    // uniform 1..=20 plus one wild point
    let mut xs: Vec<f64> = (1..=20).map(|v| v as f64).collect();
    xs.push(500.0);
    let (kept, dropped) = tukey_filter(&xs);
    assert_eq!(dropped, 1);
    assert!(!kept.contains(&500.0));
    assert_eq!(kept.len(), 20);
    // a clean sample passes through untouched, order preserved
    let clean = [5.0, 1.0, 4.0, 2.0, 3.0];
    let (kept, dropped) = tukey_filter(&clean);
    assert_eq!(dropped, 0);
    assert_eq!(kept, clean);
    // fewer than 4 samples: quartiles are meaningless, keep everything
    let (kept, dropped) = tukey_filter(&[1.0, 1e12, -1e12]);
    assert_eq!((kept.len(), dropped), (3, 0));
}
