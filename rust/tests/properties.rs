//! Cross-module property tests (in-tree testkit; proptest unavailable
//! offline). Each property runs over many seeded cases; the failing case
//! id is printed on panic for reproduction.

use pvqnet::compress::{compress_layer, decompress_layer, Codec};
use pvqnet::coordinator::{Engine, Server, ServerConfig};
use pvqnet::nn::layers::LayerParams;
use pvqnet::nn::model::{Activation, LayerSpec, ModelSpec};
use pvqnet::nn::tensor::{argmax_f32, argmax_i64, ITensor, Tensor};
use pvqnet::nn::{forward, forward_int, Model};
use pvqnet::pvq::{
    encode_fast, index_to_vector, vector_to_index, CountTable, PvqVector, RhoMode,
};
use pvqnet::quant::quantize;
use pvqnet::testkit::{check, Rng};
use std::sync::Arc;
use std::time::Duration;

/// encode → container-compress (each codec) → decompress → identical point.
#[test]
fn prop_compress_roundtrip_any_codec() {
    check("compress-roundtrip", 101, 60, |id, rng| {
        let n = 1 + rng.below(3000) as usize;
        let ratio = 1 + rng.below(6) as usize;
        let scale = 0.5 + rng.next_f64();
        let v = rng.laplacian_vec(n, scale);
        let q = encode_fast(&v, (n / ratio).max(1) as u32, RhoMode::Norm);
        let codec = match id % 4 {
            0 => Codec::ExpGolomb,
            1 => Codec::Rle,
            2 => Codec::Huffman,
            _ => Codec::Raw,
        };
        let bytes = compress_layer(&q, codec);
        let back = decompress_layer(&bytes).unwrap();
        assert_eq!(back.components, q.components);
        assert_eq!(back.k, q.k);
    });
}

/// Fischer index mapping is a bijection along random points.
#[test]
fn prop_index_bijection() {
    let table = CountTable::new(24, 24);
    check("index-bijection", 202, 100, |_, rng| {
        let n = 2 + rng.below(23) as usize;
        let k = 1 + rng.below(24) as u32;
        let v = rng.laplacian_vec(n, 1.0);
        let q = encode_fast(&v, k, RhoMode::Norm);
        let idx = vector_to_index(&q.components, &table);
        let back = index_to_vector(&idx, n, k, &table);
        assert_eq!(back, q.components);
        // rank < Np(n,k)
        assert!(idx.cmp_big(table.count(n, k as usize)) == std::cmp::Ordering::Less);
    });
}

/// Quantized ReLU nets: integer engine ≡ float-equivalent model (scaled),
/// on random architectures and random integer inputs.
#[test]
fn prop_engine_equivalence_random_mlps() {
    check("engine-equivalence", 303, 25, |_, rng| {
        let d0 = 4 + rng.below(40) as usize;
        let d1 = 2 + rng.below(24) as usize;
        let d2 = 2 + rng.below(10) as usize;
        let spec = ModelSpec {
            name: "p".into(),
            input_shape: vec![d0],
            layers: vec![
                LayerSpec::Dense { input: d0, output: d1, act: Activation::Relu },
                LayerSpec::Dense { input: d1, output: d2, act: Activation::None },
            ],
        };
        let params = vec![
            Some(LayerParams {
                w: rng.laplacian_vec(d0 * d1, 0.3).iter().map(|&v| v as f32).collect(),
                b: rng.laplacian_vec(d1, 0.1).iter().map(|&v| v as f32).collect(),
            }),
            Some(LayerParams {
                w: rng.laplacian_vec(d1 * d2, 0.3).iter().map(|&v| v as f32).collect(),
                b: rng.laplacian_vec(d2, 0.1).iter().map(|&v| v as f32).collect(),
            }),
        ];
        let model = Model { spec, params };
        let ratio = 1.0 + rng.next_f64() * 4.0;
        let q = quantize(&model, &[ratio, ratio], RhoMode::Norm).unwrap();
        for _ in 0..5 {
            let pix: Vec<u8> = (0..d0).map(|_| rng.below(256) as u8).collect();
            let xf = Tensor::from_vec(&[d0], pix.iter().map(|&b| b as f32).collect());
            let xi = ITensor::from_u8(&[d0], &pix);
            let lf = forward(&q.float_model, &xf);
            let li = forward_int(&q.quant_model, &xi).unwrap();
            for (a, b) in lf.iter().zip(&li.logits) {
                let scaled = li.scale * *b as f64;
                assert!(
                    (scaled - *a as f64).abs() < 1e-2 * (1.0 + a.abs() as f64),
                    "float {a} vs scaled-int {scaled} (ratio {ratio})"
                );
            }
        }
    });
}

/// Pyramid invariant + L2 preservation hold for every (n, k, distribution).
#[test]
fn prop_encode_invariants() {
    check("encode-invariants", 404, 200, |id, rng| {
        let n = 1 + rng.below(500) as usize;
        let k = 1 + rng.below(600) as u32;
        let v = match id % 3 {
            0 => rng.laplacian_vec(n, 1.0),
            1 => (0..n).map(|_| rng.next_gaussian()).collect(),
            _ => (0..n)
                .map(|_| if rng.next_f64() < 0.5 { 0.0 } else { rng.next_gaussian() })
                .collect(),
        };
        let q = encode_fast(&v, k, RhoMode::Norm);
        let all_zero = v.iter().all(|&x| x == 0.0);
        if all_zero {
            assert_eq!(q.rho, 0.0);
            return;
        }
        assert!(q.is_valid(), "Σ|ŷ|={} ≠ K={k}", q.l1());
        // norm-ρ preserves radius
        let rv: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        let dec = q.decode();
        let rd: f64 = dec.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((rv - rd).abs() < 1e-9 * rv.max(1.0));
    });
}

/// Coordinator under multi-client load: every request answered exactly
/// once, no cross-client result corruption.
#[test]
fn prop_coordinator_exactly_once() {
    let spec = ModelSpec {
        name: "c".into(),
        input_shape: vec![8],
        layers: vec![LayerSpec::Dense { input: 8, output: 4, act: Activation::None }],
    };
    let mut rng = Rng::new(1);
    let model = Model {
        spec,
        params: vec![Some(LayerParams {
            w: rng.gaussian_vec_f32(32, 0.3),
            b: vec![0.0; 4],
        })],
    };
    // ground truth per input
    let answer = |pix: &[u8]| -> usize {
        let t = Tensor::from_vec(&[8], pix.iter().map(|&b| b as f32).collect());
        argmax_f32(&forward(&model, &t))
    };
    let server = Arc::new(Server::start(
        Engine::Float(Arc::new(model.clone())),
        ServerConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(300),
            workers: 2,
            queue_cap: 4096,
            shards: 1,
        },
    ));
    let clients = 4;
    let per_client = 120;
    let mut handles = Vec::new();
    for ci in 0..clients {
        let server = server.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(1000 + ci);
            let mut results = Vec::new();
            for _ in 0..per_client {
                let pix: Vec<u8> = (0..8).map(|_| rng.below(256) as u8).collect();
                let rx = server.enqueue(pix.clone()).unwrap();
                results.push((pix, rx));
            }
            results
                .into_iter()
                .map(|(pix, rx)| {
                    let r = rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
                    (pix, r.class)
                })
                .collect::<Vec<_>>()
        }));
    }
    let mut total = 0;
    for h in handles {
        for (pix, class) in h.join().unwrap() {
            assert_eq!(class, answer(&pix), "cross-request corruption");
            total += 1;
        }
    }
    assert_eq!(total, clients * per_client);
    let m = server.metrics();
    assert_eq!(
        m.responses.load(std::sync::atomic::Ordering::Relaxed),
        (clients * per_client) as u64
    );
}

/// bsign integer path: argmax equals a big-integer exact recomputation.
#[test]
fn prop_bsign_binary_engine_vs_integer() {
    use pvqnet::nn::binary::{BinaryDense, BitVec};
    check("binary-vs-integer", 505, 40, |_, rng| {
        let n_in = 8 + rng.below(200) as usize;
        let n_out = 1 + rng.below(30) as usize;
        let v = rng.laplacian_vec(n_in * n_out + n_out, 0.4);
        let q = encode_fast(&v, ((n_in * n_out) / 3).max(1) as u32, RhoMode::Norm);
        let (w, b) = q.components.split_at(n_in * n_out);
        let x: Vec<i64> =
            (0..n_in).map(|_| if rng.next_u64() & 1 == 1 { 1 } else { -1 }).collect();
        let mut ops = pvqnet::nn::pvq_engine::OpCount::default();
        let expect =
            pvqnet::nn::pvq_engine::dense_i64(&x, w, b, n_in, n_out, &mut ops);
        let bd = BinaryDense::compile(w, b, n_in, n_out);
        let got = bd.forward(&BitVec::from_pm1(&x).unwrap());
        assert_eq!(got, expect);
        assert_eq!(argmax_i64(&got), argmax_i64(&expect));
    });
}
