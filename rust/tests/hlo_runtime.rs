//! PJRT integration: the AOT-lowered HLO graphs must load, compile, run,
//! and agree with the rust float engine on the same weights and inputs.
//!
//! Skipped with a notice when `make artifacts` has not been run.

use pvqnet::data::Dataset;
use pvqnet::nn::weights::load_model;
use pvqnet::nn::{forward, ModelSpec, Tensor};
use pvqnet::runtime::HloModel;
use std::path::Path;

const BATCH: usize = 32;

fn have_artifacts() -> bool {
    Path::new("artifacts/manifest.txt").exists()
}

#[test]
fn net_a_hlo_matches_rust_float_engine() {
    if !have_artifacts() {
        eprintln!("SKIP hlo_runtime: run `make artifacts` first");
        return;
    }
    let spec = ModelSpec::by_name("a").unwrap();
    let model = load_model(Path::new("artifacts/net_a.pvqw"), &spec).unwrap();
    let hlo = HloModel::load(Path::new("artifacts/net_a.hlo.txt"), BATCH, 784, 10).unwrap();
    let data = Dataset::load(Path::new("artifacts/mnist_test.bin")).unwrap();

    let mut x = vec![0f32; BATCH * 784];
    for i in 0..BATCH {
        for (j, &b) in data.sample(i).iter().enumerate() {
            x[i * 784 + j] = b as f32;
        }
    }
    let logits = hlo.run_batch(&x).unwrap();
    for i in 0..BATCH {
        let t = Tensor::from_vec(&[784], x[i * 784..(i + 1) * 784].to_vec());
        let want = forward(&model, &t);
        let got = &logits[i * 10..(i + 1) * 10];
        for (a, b) in want.iter().zip(got) {
            assert!(
                (a - b).abs() < 1e-2 * (1.0 + a.abs()),
                "sample {i}: rust {a} vs pjrt {b}"
            );
        }
    }
}

#[test]
fn pallas_lowered_hlo_matches_plain_hlo() {
    if !have_artifacts() {
        eprintln!("SKIP hlo_runtime: run `make artifacts` first");
        return;
    }
    let plain = HloModel::load(Path::new("artifacts/net_a.hlo.txt"), BATCH, 784, 10).unwrap();
    let pallas = HloModel::load(Path::new("artifacts/net_a_pallas.hlo.txt"), BATCH, 784, 10).unwrap();
    let data = Dataset::load(Path::new("artifacts/mnist_test.bin")).unwrap();
    let mut x = vec![0f32; BATCH * 784];
    for i in 0..BATCH {
        for (j, &b) in data.sample(i + BATCH).iter().enumerate() {
            x[i * 784 + j] = b as f32;
        }
    }
    let a = plain.run_batch(&x).unwrap();
    let b = pallas.run_batch(&x).unwrap();
    for (i, (va, vb)) in a.iter().zip(&b).enumerate() {
        assert!(
            (va - vb).abs() < 1e-2 * (1.0 + va.abs()),
            "logit {i}: plain {va} vs pallas-kernel {vb}"
        );
    }
}

#[test]
fn quantized_hlo_loads_and_classifies() {
    if !have_artifacts() {
        eprintln!("SKIP hlo_runtime: run `make artifacts` first");
        return;
    }
    let hlo = HloModel::load(Path::new("artifacts/net_a_pvq.hlo.txt"), BATCH, 784, 10).unwrap();
    let data = Dataset::load(Path::new("artifacts/mnist_test.bin")).unwrap();
    let mut x = vec![0f32; BATCH * 784];
    for i in 0..BATCH {
        for (j, &b) in data.sample(i).iter().enumerate() {
            x[i * 784 + j] = b as f32;
        }
    }
    let classes = hlo.classify_batch(&x).unwrap();
    let correct = classes
        .iter()
        .enumerate()
        .filter(|(i, &c)| c == data.labels[*i] as usize)
        .count();
    // quantized net at paper ratios should stay way above chance
    assert!(correct * 2 >= BATCH, "quantized HLO accuracy {correct}/{BATCH}");
}

#[test]
fn hlo_engine_serves_through_coordinator() {
    if !have_artifacts() {
        eprintln!("SKIP hlo_runtime: run `make artifacts` first");
        return;
    }
    use pvqnet::coordinator::{Classify, ClassifyRequest, Engine, Server, ServerConfig};
    use std::sync::Arc;
    let hlo = HloModel::load(Path::new("artifacts/net_a.hlo.txt"), BATCH, 784, 10).unwrap();
    let data = Dataset::load(Path::new("artifacts/mnist_test.bin")).unwrap();
    let server = Server::start(Engine::Hlo(Arc::new(hlo)), ServerConfig::default());
    let mut correct = 0;
    let n = 64;
    for i in 0..n {
        let r = server
            .submit(ClassifyRequest::single(data.sample(i).to_vec()))
            .unwrap();
        if r.results[0].class == data.labels[i] as usize {
            correct += 1;
        }
    }
    assert!(correct * 2 > n, "served accuracy {correct}/{n}");
    server.shutdown();
}
