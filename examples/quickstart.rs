//! Quickstart: the whole PVQ story in one file, no artifacts required.
//!
//!     cargo run --release --example quickstart
//!
//! 1. PVQ-encode a vector, inspect the pyramid point and gain
//! 2. dot products: exact float vs PVQ approximation, op counts
//! 3. quantize a small trained-ish model and compare accuracy
//! 4. compress the weights and show bits/weight
//! 5. simulate the paper's hardware circuits

use pvqnet::compress::{codec_survey, Distribution};
use pvqnet::data::synth_glyphs;
use pvqnet::hw::{add_only_arch, mult_arch};
use pvqnet::nn::{Activation, LayerSpec, ModelSpec};
use pvqnet::pvq::{cosine, encode_opt, CountTable, RhoMode};
use pvqnet::quant::{evaluate, quantize};
use pvqnet::testkit::Rng;

fn main() -> anyhow::Result<()> {
    println!("== 1. PVQ encoding (paper §II)");
    let mut rng = Rng::new(42);
    let v: Vec<f64> = rng.laplacian_vec(16, 1.0);
    let q = encode_opt(&v, 8, RhoMode::Norm);
    println!("v      = {:?}", v.iter().map(|x| (x * 100.0).round() / 100.0).collect::<Vec<_>>());
    println!("ŷ∈P(16,8) = {:?}  (Σ|ŷ|={} = K)", q.components, q.l1());
    println!("ρ = {:.4}, cosine(v, ŷ) = {:.4}", q.rho, cosine(&v, &q));
    let t = CountTable::new(16, 8);
    println!(
        "Nₚ(16,8) = {} → {} bits fixed-rate (vs 16×32 raw f32 bits)",
        t.count(16, 8),
        t.index_bits(16, 8)
    );

    println!("\n== 2. dot products (paper §III, §VIII)");
    let x: Vec<i64> = (0..16).map(|_| rng.below(256) as i64).collect();
    let m = mult_arch(&q.components, &x);
    let a = add_only_arch(&q.components, &x);
    println!("mult-arch : value {} in {} cycles (one per nonzero)", m.value, m.cycles);
    println!("add-only  : value {} in {} cycles (exactly K)", a.value, a.cycles);

    println!("\n== 3. quantize a model (paper §IV/§VII)");
    let train = synth_glyphs(400, 16, 16, 1);
    let test = synth_glyphs(200, 16, 16, 2);
    // template-matching readout as a stand-in for a trained net
    let d = train.sample_len();
    let mut w = Vec::with_capacity(10 * d);
    for c in 0..10 {
        let mut mean = vec![0f64; d];
        let mut cnt = 0.0f64;
        for i in 0..train.n {
            if train.labels[i] as usize == c {
                cnt += 1.0;
                for (j, &p) in train.sample(i).iter().enumerate() {
                    mean[j] += p as f64;
                }
            }
        }
        let norm: f64 = mean.iter().map(|v| v * v).sum::<f64>().sqrt().max(1e-9);
        w.extend(mean.iter().map(|&v| (v / cnt.max(1.0) / norm) as f32));
    }
    let spec = ModelSpec {
        name: "quickstart".into(),
        input_shape: vec![d],
        layers: vec![LayerSpec::Dense { input: d, output: 10, act: Activation::None }],
    };
    let model = pvqnet::nn::Model {
        spec,
        params: vec![Some(pvqnet::nn::LayerParams { w, b: vec![0.0; 10] })],
    };
    let quantized = quantize(&model, &[5.0], RhoMode::Norm)?;
    let rep = evaluate(&model, &quantized, &test, 200)?;
    println!("{}", rep.render());

    println!("\n== 4. weight compression (paper §VI)");
    let layer = quantized.quant_model.layers.iter().flatten().next().unwrap();
    let dist = Distribution::from_values(&layer.w);
    println!("{}", dist.table_row("FC0"));
    let mut comps = layer.w.clone();
    comps.extend_from_slice(&layer.b_pyramid);
    let pv = pvqnet::pvq::PvqVector { k: layer.k, components: comps, rho: layer.rho };
    for (name, bpw) in codec_survey(&pv) {
        println!("  {name:<16} {bpw:>7.3} bits/weight");
    }

    println!("\n== 5. next steps");
    println!("  make artifacts            # train the paper's nets A–D (python, once)");
    println!("  pvqnet eval --net a       # §VII accuracy before/after");
    println!("  pvqnet serve --net b      # batching inference server");
    Ok(())
}
