//! END-TO-END DRIVER (docs/ARCHITECTURE.md §4): exercises every layer
//! of the stack on the real (synthetic-MNIST) workload:
//!
//!   L2/L1 artifacts → rust weight loader → PVQ quantization →
//!   float engine + integer PVQ engine + PJRT HLO engine →
//!   §VII accuracy tables, §VI compression, §VIII cycles →
//!   batched serving with latency/throughput.
//!
//!     make artifacts && cargo run --release --example mnist_pvq_pipeline

use pvqnet::coordinator::{Engine, Server, ServerConfig};
use pvqnet::data::Dataset;
use pvqnet::hw::HwReport;
use pvqnet::nn::weights::load_model;
use pvqnet::nn::ModelSpec;
use pvqnet::pvq::RhoMode;
use pvqnet::quant::{distribution_table, evaluate, quantize, ratio_sweep};
use pvqnet::runtime::HloModel;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    anyhow::ensure!(
        dir.join("manifest.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );

    // ---------- load trained net A + test data
    let spec = ModelSpec::by_name("a").unwrap();
    let model = load_model(&dir.join("net_a.pvqw"), &spec)?;
    let data = Dataset::load(&dir.join("mnist_test.bin"))?;
    println!("net A loaded: {} params, test set {}×{}px\n", spec.total_params(), data.n, data.h);
    println!("{}", spec.anatomy_table(&spec.paper_ratios()));

    // ---------- §VII: quantize at paper ratios, before/after accuracy
    let q = quantize(&model, &spec.paper_ratios(), RhoMode::Norm)?;
    let rep = evaluate(&model, &q, &data, 500)?;
    println!("—— §VII accuracy (Table-1 ratios) ——");
    println!("{}\n", rep.render());

    // ---------- Tables 5-ish: weight distribution
    println!("—— Table 5 (weight distribution after PVQ) ——");
    println!("{}", distribution_table(&q));

    // ---------- §VI: compression survey on FC0
    println!("—— §VI compression (FC0) ——");
    let fc0 = q.quant_model.layers.iter().flatten().next().unwrap();
    let mut comps = fc0.w.clone();
    comps.extend_from_slice(&fc0.b_pyramid);
    let pv = pvqnet::pvq::PvqVector { k: fc0.k, components: comps, rho: fc0.rho };
    for (name, bpw) in pvqnet::compress::codec_survey(&pv) {
        println!("  {name:<16} {bpw:>7.3} bits/weight");
    }

    // ---------- §VIII: hardware cycles
    println!("\n—— §VIII hardware report ——");
    println!("{}", HwReport::from_model(&q.quant_model).render());

    // ---------- ratio sweep (the paper's §IV iteration)
    println!("—— N/K sweep (200 samples) ——");
    for p in ratio_sweep(&model, &data, &[1.0, 2.0, 3.0, 5.0, 8.0], 200)? {
        println!(
            "  N/K {:>4.1} → accuracy {:>6.2}%  mean-cosine {:.4}  total-K {}",
            p.ratio,
            100.0 * p.accuracy,
            p.mean_cosine,
            p.total_k
        );
    }

    // ---------- serving: PJRT float vs integer PVQ engine
    println!("\n—— serving (batched, 400 requests each) ——");
    let hlo = HloModel::load(&dir.join("net_a.hlo.txt"), 32, 784, 10)?;
    let compiled = Arc::new(pvqnet::nn::CompiledQuantModel::compile(&q.quant_model)?);
    for (name, engine) in [
        ("hlo-pjrt", Engine::Hlo(Arc::new(hlo))),
        ("pvq-int", Engine::PvqInt(Arc::new(q.quant_model.clone()))),
        ("pvq-csr", Engine::PvqCompiled(compiled, spec.input_shape.clone())),
    ] {
        let server = Server::start(
            engine,
            ServerConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(2),
                workers: 1,
                queue_cap: 4096,
                shards: 1,
            },
        );
        let t0 = std::time::Instant::now();
        let n = 400;
        let mut correct = 0;
        for i in 0..n {
            let r = server.classify(data.sample(i % data.n).to_vec())?;
            if r.class == data.labels[i % data.n] as usize {
                correct += 1;
            }
        }
        let dt = t0.elapsed();
        println!(
            "  {:<9} {:>7.0} req/s  accuracy {:>6.2}%  [{}]",
            name,
            n as f64 / dt.as_secs_f64(),
            100.0 * correct as f64 / n as f64,
            server.metrics().summary()
        );
        server.shutdown();
    }
    Ok(())
}
