//! Serving demo: multi-model router with the float PJRT graph, the
//! PVQ-quantized PJRT graph, and the pure-integer PVQ engine side by side,
//! under concurrent client load.
//!
//!     make artifacts && cargo run --release --example serve_demo

use pvqnet::coordinator::{Engine, Router, ServerConfig};
use pvqnet::data::Dataset;
use pvqnet::nn::weights::load_model;
use pvqnet::nn::ModelSpec;
use pvqnet::pvq::RhoMode;
use pvqnet::quant::quantize;
use pvqnet::runtime::HloModel;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    anyhow::ensure!(
        dir.join("manifest.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let spec = ModelSpec::by_name("a").unwrap();
    let model = load_model(&dir.join("net_a.pvqw"), &spec)?;
    let data = Arc::new(Dataset::load(&dir.join("mnist_test.bin"))?);
    let q = quantize(&model, &spec.paper_ratios(), RhoMode::Norm)?;

    let engines = vec![
        (
            "float-hlo".to_string(),
            Engine::Hlo(Arc::new(HloModel::load(&dir.join("net_a.hlo.txt"), 32, 784, 10)?)),
        ),
        (
            "pvq-hlo".to_string(),
            Engine::Hlo(Arc::new(HloModel::load(&dir.join("net_a_pvq.hlo.txt"), 32, 784, 10)?)),
        ),
        ("pvq-int".to_string(), Engine::PvqInt(Arc::new(q.quant_model))),
    ];
    let router = Arc::new(Router::new(
        engines,
        "pvq-int",
        ServerConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            workers: 1,
            queue_cap: 8192,
            shards: 1,
        },
    )?);

    // concurrent clients hammering different routes
    let routes = ["float-hlo", "pvq-hlo", "pvq-int"];
    let per_client = 300usize;
    let mut handles = Vec::new();
    let t0 = std::time::Instant::now();
    for (ci, route) in routes.iter().enumerate() {
        let router = router.clone();
        let data = data.clone();
        let route = route.to_string();
        handles.push(std::thread::spawn(move || -> anyhow::Result<(String, usize)> {
            let mut correct = 0;
            for i in 0..per_client {
                let idx = (ci * 131 + i) % data.n;
                let r = router.classify(Some(&route), data.sample(idx).to_vec())?;
                if r.class == data.labels[idx] as usize {
                    correct += 1;
                }
            }
            Ok((route, correct))
        }));
    }
    for h in handles {
        let (route, correct) = h.join().unwrap()?;
        println!(
            "route {:<10} accuracy {:>6.2}% over {} requests",
            route,
            100.0 * correct as f64 / per_client as f64,
            per_client
        );
    }
    let dt = t0.elapsed();
    println!(
        "\ntotal {} requests in {:.2}s → {:.0} req/s aggregate",
        routes.len() * per_client,
        dt.as_secs_f64(),
        (routes.len() * per_client) as f64 / dt.as_secs_f64()
    );
    println!("{}", router.summary());
    match Arc::try_unwrap(router) {
        Ok(r) => r.shutdown(),
        Err(_) => unreachable!("all clients joined"),
    }
    Ok(())
}
