//! Net-B compression study (§VI of the paper): per-layer codec survey,
//! Table-6 distributions, whole-model compressed size, and the Fischer
//! fixed-rate bound — on the trained CIFAR CNN.
//!
//!     make artifacts && cargo run --release --example cifar_compression

use pvqnet::compress::{codec_survey, compress_layer, decompress_layer, Codec};
use pvqnet::nn::weights::load_model;
use pvqnet::nn::ModelSpec;
use pvqnet::pvq::{np_bits_estimate, RhoMode};
use pvqnet::quant::{distribution_table, quantize};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    anyhow::ensure!(
        dir.join("manifest.txt").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let spec = ModelSpec::by_name("b").unwrap();
    let model = load_model(&dir.join("net_b.pvqw"), &spec)?;
    let q = quantize(&model, &spec.paper_ratios(), RhoMode::Norm)?;

    println!("—— Table 6: weight distribution per layer ——");
    println!("{}", distribution_table(&q));

    println!("—— §VI codec survey per layer ——");
    let mut total_raw = 0u64;
    let mut total_best = 0u64;
    for (r, &li) in q.reports.iter().zip(&spec.weighted_layers()) {
        let layer = q.quant_model.layers[li].as_ref().unwrap();
        let mut comps = layer.w.clone();
        comps.extend_from_slice(&layer.b_pyramid);
        let pv = pvqnet::pvq::PvqVector { k: layer.k, components: comps, rho: layer.rho };
        println!("{} (N={}, K={}, N/K={:.2}):", r.label, r.n, r.k, r.ratio);
        let survey = codec_survey(&pv);
        for (name, bpw) in &survey {
            println!("  {name:<16} {bpw:>7.3} bits/weight");
        }
        let best = survey
            .iter()
            .filter(|(n, _)| n != "entropy-bound" && n != "raw-f32" && n != "fischer-index")
            .map(|(_, b)| *b)
            .fold(f64::INFINITY, f64::min);
        total_raw += r.n as u64 * 32;
        total_best += (best * r.n as f64).ceil() as u64;

        // container roundtrip proves losslessness on the real layer
        let bytes = compress_layer(&pv, Codec::Rle);
        let back = decompress_layer(&bytes)?;
        assert_eq!(back.components, pv.components, "roundtrip failed");
    }
    println!(
        "whole model: {} → {} bits ({:.1}× compression, lossless given ρ's)",
        total_raw,
        total_best,
        total_raw as f64 / total_best as f64
    );

    println!("\n—— Fischer fixed-rate bound (log₂ Nₚ per layer) ——");
    for r in &q.reports {
        let bits = np_bits_estimate(r.n as u64, r.k as u64);
        println!(
            "  {:<7} log₂Nₚ({}, {}) = {:.0} bits → {:.3} bits/weight",
            r.label,
            r.n,
            r.k,
            bits,
            bits / r.n as f64
        );
    }
    Ok(())
}
