"""Pyramid Vector Quantization — python reference encoder.

Mirrors ``rust/src/pvq/encode.rs::encode_fast`` operation-for-operation so
the two implementations can be golden-tested against each other:

* sequential (non-pairwise) f64 accumulation of the L1 norm
* targets t_i = K * |v_i| / l1
* magnitudes y_i = floor(t_i + 0.5)
* pulse-sum correction by largest/smallest rounding error, ties on index

This is the build-time encoder used by ``aot.py`` to produce the
PVQ-quantized HLO variants; the request path always uses the rust encoder.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence


@dataclass
class PvqVector:
    """Integer pyramid point plus gain (product PVQ, eq. 2 of the paper)."""

    k: int
    components: list[int]
    rho: float

    def l1(self) -> int:
        return sum(abs(c) for c in self.components)

    def is_valid(self) -> bool:
        return self.l1() == self.k

    def decode(self) -> list[float]:
        return [self.rho * c for c in self.components]


def encode_fast(v: Sequence[float], k: int, rho_mode: str = "norm") -> PvqVector:
    """Scale-round-correct PVQ encoder (see module docstring).

    rho_mode: "norm" (paper, r/||ŷ||₂) or "lsq" (least-squares gain).
    """
    n = len(v)
    l1 = 0.0
    for x in v:
        l1 += abs(x)
    if l1 == 0.0 or k == 0:
        return PvqVector(0, [0] * n, 0.0)

    y = [0] * n
    err = [0.0] * n
    total = 0
    for i, x in enumerate(v):
        t = k * abs(x) / l1
        r = math.floor(t + 0.5)
        y[i] = int(r)
        err[i] = r - t
        total += int(r)

    if total != k:
        if total > k:
            order = sorted(range(n), key=lambda i: (-err[i], i))
            excess = total - k
            idx = 0
            while excess > 0:
                i = order[idx % n]
                if y[i] > 0:
                    y[i] -= 1
                    err[i] -= 1.0
                    excess -= 1
                idx += 1
                if idx % n == 0:
                    order = sorted(range(n), key=lambda i: (-err[i], i))
        else:
            order = sorted(range(n), key=lambda i: (err[i], i))
            deficit = k - total
            idx = 0
            while deficit > 0:
                i = order[idx % n]
                y[i] += 1
                err[i] += 1.0
                deficit -= 1
                idx += 1
                if idx % n == 0:
                    order = sorted(range(n), key=lambda i: (err[i], i))

    comps = [-m if x < 0.0 else m for m, x in zip(y, v)]
    energy = float(sum(c * c for c in comps))
    if energy == 0.0:
        rho = 0.0
    elif rho_mode == "norm":
        r2 = 0.0
        for x in v:
            r2 += x * x
        rho = math.sqrt(r2) / math.sqrt(energy)
    elif rho_mode == "lsq":
        corr = 0.0
        for x, c in zip(v, comps):
            corr += x * c
        rho = max(corr / energy, 0.0)
    else:
        raise ValueError(f"unknown rho_mode {rho_mode}")
    assert sum(abs(c) for c in comps) == k, "pyramid invariant violated"
    return PvqVector(k, comps, rho)


def quantize_layer_weights(w_flat, b, ratio: float, input_scale: float = 1.0):
    """The paper's §VII per-layer procedure (mirrors rust quant::apply):

    flatten weights ++ (biases / input_scale), PVQ-encode at
    K = max(1, round(N / ratio)), return (w_q, b_q, components, rho, k)
    where w_q/b_q are the float-equivalent substituted parameters.
    """
    import numpy as np

    w_flat = np.asarray(w_flat, dtype=np.float64).ravel()
    b = np.asarray(b, dtype=np.float64).ravel()
    flat = list(w_flat) + [x / input_scale for x in b]
    n = len(flat)
    k = max(1, int(round(n / ratio)))
    q = encode_fast(flat, k)
    comps = np.array(q.components, dtype=np.int32)
    wq = (q.rho * comps[: len(w_flat)]).astype(np.float32)
    bq = (q.rho * input_scale * comps[len(w_flat):]).astype(np.float32)
    return wq, bq, comps, q.rho, k
