"""L2: the paper's nets A–D in pure jax (Tables 1–4).

* A — MNIST MLP 784-512-512-10, ReLU
* B — CIFAR CNN conv32,32 / pool / conv64,64 / pool / fc512 / fc10, ReLU
* C — A with bsign activations + straight-through estimator (§VII, eq. 17/18)
* D — B with bsign + STE

Dense layers can route through the L1 Pallas kernel (``use_pallas=True``)
so the kernel lowers into the same HLO the rust runtime executes.

Input convention: raw u8 pixel values as f32 (0..255) — matching the
rust engines and the paper's integer-input nets. The 1/255 normalization
used during training is *folded into the first layer's weights* at export
(``fold_input_scale``), keeping train-time conditioning and inference-time
raw-pixel semantics exactly consistent.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .kernels.pvq_matmul import pvq_matmul


# ---------------------------------------------------------------- bsign/STE
@jax.custom_vjp
def bsign(x):
    """eq. 17: +1 for x ≥ 0, −1 otherwise."""
    return jnp.where(x >= 0, 1.0, -1.0)


def _bsign_fwd(x):
    return bsign(x), None


def _bsign_bwd(_, g):
    # eq. 18 (Hinton's straight-through estimator): d/dx bsign(x) := 1
    return (g,)


bsign.defvjp(_bsign_fwd, _bsign_bwd)


def _act(x, kind: str):
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "bsign":
        return bsign(x)
    if kind == "none":
        return x
    raise ValueError(kind)


# ---------------------------------------------------------------- params
def init_mlp(key, sizes=(784, 512, 512, 10)):
    """Net A/C parameters: list of dense {'w': [out,in], 'b': [out]}."""
    params = []
    for i in range(len(sizes) - 1):
        key, k1 = jax.random.split(key)
        fan_in = sizes[i]
        w = jax.random.normal(k1, (sizes[i + 1], sizes[i])) * jnp.sqrt(2.0 / fan_in)
        params.append({"w": w, "b": jnp.zeros((sizes[i + 1],))})
    return params


def init_cnn(key):
    """Net B/D parameters. Convs are HWIO; dense rows are out-major."""
    params = []
    shapes = [
        ("conv", (3, 3, 3, 32)),
        ("conv", (3, 3, 32, 32)),
        ("conv", (3, 3, 32, 64)),
        ("conv", (3, 3, 64, 64)),
        ("dense", (512, 4096)),
        ("dense", (10, 512)),
    ]
    for kind, shp in shapes:
        key, k1 = jax.random.split(key)
        if kind == "conv":
            fan_in = shp[0] * shp[1] * shp[2]
            w = jax.random.normal(k1, shp) * jnp.sqrt(2.0 / fan_in)
            params.append({"w": w, "b": jnp.zeros((shp[3],))})
        else:
            fan_in = shp[1]
            w = jax.random.normal(k1, shp) * jnp.sqrt(2.0 / fan_in)
            params.append({"w": w, "b": jnp.zeros((shp[0],))})
    return params


# ---------------------------------------------------------------- forward
def dense_apply(p, x, use_pallas: bool):
    if use_pallas:
        return pvq_matmul(x, p["w"], p["b"], 1.0)
    return x @ p["w"].T + p["b"][None, :]


def _dropout(h, rate, key):
    if key is None or rate <= 0.0:
        return h
    keep = 1.0 - rate
    mask = jax.random.bernoulli(key, keep, h.shape)
    return jnp.where(mask, h / keep, 0.0)


def mlp_forward(params, x, act: str = "relu", use_pallas: bool = False, dropout_key=None):
    """Net A/C forward. x: [B, 784] raw-pixel f32. Returns logits [B, 10].

    `dropout_key` enables the paper's Table-1 dropout (0.2 after each
    hidden layer) during training; inference leaves it None.
    """
    h = x
    for i, p in enumerate(params[:-1]):
        h = _act(dense_apply(p, h, use_pallas), act)
        if dropout_key is not None:
            h = _dropout(h, 0.2, jax.random.fold_in(dropout_key, i))
    return dense_apply(params[-1], h, use_pallas)


def _conv(p, x):
    y = jax.lax.conv_general_dilated(
        x,
        p["w"],
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"][None, None, None, :]


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_forward(params, x, act: str = "relu", use_pallas: bool = False, dropout_key=None):
    """Net B/D forward. x: [B, 32, 32, 3] raw-pixel f32 → logits [B, 10].

    `dropout_key` enables Table-2 dropout (0.25 / 0.25 / 0.5).
    """
    h = _act(_conv(params[0], x), act)
    h = _act(_conv(params[1], h), act)
    h = _pool(h)
    if dropout_key is not None:
        h = _dropout(h, 0.25, jax.random.fold_in(dropout_key, 0))
    h = _act(_conv(params[2], h), act)
    h = _act(_conv(params[3], h), act)
    h = _pool(h)
    if dropout_key is not None:
        h = _dropout(h, 0.25, jax.random.fold_in(dropout_key, 1))
    h = h.reshape(h.shape[0], -1)  # [B, 4096] (HWC order = rust Flatten)
    h = _act(dense_apply(params[4], h, use_pallas), act)
    if dropout_key is not None:
        h = _dropout(h, 0.5, jax.random.fold_in(dropout_key, 2))
    return dense_apply(params[5], h, use_pallas)


def fold_input_scale(params, scale: float):
    """Fold a 1/scale input normalization into the first layer so the
    exported model consumes raw pixels: W₀ ← W₀/scale (bias unchanged)."""
    out = [dict(p) for p in params]
    out[0] = {"w": out[0]["w"] / scale, "b": out[0]["b"]}
    return out


# ---------------------------------------------------------------- training
def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


@functools.partial(jax.jit, static_argnames=("forward_name", "act", "use_dropout"))
def _loss_and_grad(params, x, y, key, forward_name: str, act: str, use_dropout: bool):
    fwd = {"mlp": mlp_forward, "cnn": cnn_forward}[forward_name]

    def loss_fn(p):
        dk = key if use_dropout else None
        return cross_entropy(fwd(p, x, act=act, dropout_key=dk), y)

    return jax.value_and_grad(loss_fn)(params)


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


@jax.jit
def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, wd=1e-4):
    """AdamW: decoupled weight decay — §IV of the paper notes L1/L2
    regularization sparsifies weights and helps PVQ encoding."""
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p), params, mh, vh
    )
    return new, {"m": m, "v": v, "t": t}


def train(
    params,
    images: Any,
    labels: Any,
    forward_name: str,
    act: str,
    steps: int,
    batch: int = 64,
    lr: float = 1e-3,
    seed: int = 0,
    log_every: int = 50,
):
    """Minibatch Adam on normalized images (x/255). Returns trained params
    (still in normalized-input convention — fold before export)."""
    import numpy as np

    x_all = np.asarray(images, dtype=np.float32).reshape(len(images), *images.shape[1:]) / 255.0
    if forward_name == "mlp":
        x_all = x_all.reshape(len(x_all), -1)
    y_all = np.asarray(labels, dtype=np.int32)
    rng = np.random.RandomState(seed)
    state = adam_init(params)
    history = []
    use_dropout = act == "relu"  # paper: dropout for A/B; none for C/D
    for s in range(steps):
        idx = rng.randint(0, len(x_all), size=batch)
        key = jax.random.PRNGKey(seed * 100003 + s)
        loss, grads = _loss_and_grad(
            params, jnp.asarray(x_all[idx]), jnp.asarray(y_all[idx]), key, forward_name, act, use_dropout
        )
        params, state = adam_update(params, grads, state, lr=lr)
        if s % log_every == 0 or s == steps - 1:
            history.append((s, float(loss)))
            print(f"  step {s:5d} loss {float(loss):.4f}")
    return params, history


def evaluate(params, images, labels, forward_name: str, act: str, batch: int = 256) -> float:
    """Accuracy with normalized inputs (training convention)."""
    import numpy as np

    x_all = np.asarray(images, dtype=np.float32) / 255.0
    if forward_name == "mlp":
        x_all = x_all.reshape(len(x_all), -1)
    y_all = np.asarray(labels, dtype=np.int64)
    fwd = {"mlp": mlp_forward, "cnn": cnn_forward}[forward_name]
    correct = 0
    for i in range(0, len(x_all), batch):
        logits = fwd(params, jnp.asarray(x_all[i : i + batch]), act=act)
        correct += int(jnp.sum(jnp.argmax(logits, axis=1) == jnp.asarray(y_all[i : i + batch])))
    return correct / len(x_all)
