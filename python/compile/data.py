"""Synthetic datasets (no-network substitution for MNIST / CIFAR10).

See docs/ARCHITECTURE.md §3: PVQ's behaviour depends on trained weight statistics,
not on the exact pixels, so any natural-ish classification task with the
same shapes exercises the same code paths.

* ``synth_mnist``  — 28×28×1: ten 7×5 digit glyph templates rendered with
  random shift, per-pixel noise and brightness jitter.
* ``synth_cifar``  — 32×32×3: ten classes, each a (color palette,
  oriented sinusoidal texture frequency) pair with additive noise and a
  random phase — CNN-learnable, MLP-hostile, like the real thing.

Both are deterministic in the seed and emit u8 NHWC arrays + u8 labels.
"""

from __future__ import annotations

import numpy as np

GLYPHS = np.array(
    [
        [0b01110, 0b10001, 0b10011, 0b10101, 0b11001, 0b10001, 0b01110],
        [0b00100, 0b01100, 0b00100, 0b00100, 0b00100, 0b00100, 0b01110],
        [0b01110, 0b10001, 0b00001, 0b00110, 0b01000, 0b10000, 0b11111],
        [0b01110, 0b10001, 0b00001, 0b00110, 0b00001, 0b10001, 0b01110],
        [0b00010, 0b00110, 0b01010, 0b10010, 0b11111, 0b00010, 0b00010],
        [0b11111, 0b10000, 0b11110, 0b00001, 0b00001, 0b10001, 0b01110],
        [0b00110, 0b01000, 0b10000, 0b11110, 0b10001, 0b10001, 0b01110],
        [0b11111, 0b00001, 0b00010, 0b00100, 0b01000, 0b01000, 0b01000],
        [0b01110, 0b10001, 0b10001, 0b01110, 0b10001, 0b10001, 0b01110],
        [0b01110, 0b10001, 0b10001, 0b01111, 0b00001, 0b00010, 0b01100],
    ],
    dtype=np.uint8,
)


def _glyph_bitmap(cls: int) -> np.ndarray:
    rows = GLYPHS[cls]
    bm = np.zeros((7, 5), dtype=np.float32)
    for y in range(7):
        for x in range(5):
            bm[y, x] = (rows[y] >> (4 - x)) & 1
    return bm


def synth_mnist(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """n samples of 28×28×1 u8 glyph images; labels round-robin 0..9."""
    rng = np.random.RandomState(seed)
    images = np.zeros((n, 28, 28, 1), dtype=np.uint8)
    labels = (np.arange(n) % 10).astype(np.uint8)
    scale = 3  # 7x5 glyph -> 21x15
    for i in range(n):
        bm = _glyph_bitmap(labels[i])
        big = np.kron(bm, np.ones((scale, scale), dtype=np.float32))  # 21x15
        # heavy background noise so the task is not trivially separable
        img = rng.randint(0, 90, size=(28, 28)).astype(np.float32)
        oy = rng.randint(0, 28 - 21 + 1)
        ox = rng.randint(0, 28 - 15 + 1)
        bright = rng.uniform(0.55, 1.0)
        patch = img[oy : oy + 21, ox : ox + 15]
        glyph = 90.0 + big * bright * 165.0
        img[oy : oy + 21, ox : ox + 15] = np.where(big > 0, glyph, patch)
        # pixel dropout inside the glyph
        noise = rng.uniform(size=(21, 15)) < 0.12
        img[oy : oy + 21, ox : ox + 15][noise & (big > 0)] = rng.randint(0, 90)
        # random occluding block
        if rng.uniform() < 0.5:
            by, bx = rng.randint(0, 22), rng.randint(0, 22)
            img[by : by + 5, bx : bx + 5] = rng.randint(0, 255)
        # distractor stroke
        if rng.uniform() < 0.5:
            ry = rng.randint(0, 28)
            img[ry, :] = np.maximum(img[ry, :], rng.randint(80, 200))
        images[i, :, :, 0] = np.clip(img, 0, 255).astype(np.uint8)
    return images, labels


def synth_cifar(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """n samples of 32×32×3 u8 procedural-texture images, 10 classes."""
    rng = np.random.RandomState(seed)
    images = np.zeros((n, 32, 32, 3), dtype=np.uint8)
    labels = (np.arange(n) % 10).astype(np.uint8)
    yy, xx = np.meshgrid(np.arange(32), np.arange(32), indexing="ij")
    # class -> (palette rgb, spatial frequency, orientation)
    palettes = np.array(
        [
            [200, 60, 60], [60, 200, 60], [60, 60, 200], [200, 200, 60],
            [200, 60, 200], [60, 200, 200], [230, 150, 40], [120, 120, 220],
            [160, 220, 120], [220, 120, 160],
        ],
        dtype=np.float32,
    )
    freqs = np.array([0.2, 0.45, 0.2, 0.45, 0.2, 0.45, 0.2, 0.45, 0.2, 0.45])
    thetas = np.array([0.0, 0.0, 0.9, 0.9, 0.0, 0.9, 0.45, 0.45, 1.35, 1.35])
    # pull palettes toward gray and make class pairs share a palette so
    # color alone cannot separate them — texture must be learned
    palettes = 0.35 * palettes + 0.65 * 128.0
    palettes = palettes[np.array([0, 0, 1, 1, 2, 2, 3, 3, 4, 4])]
    for i in range(n):
        c = labels[i]
        phase = rng.uniform(0, 2 * np.pi)
        freq = freqs[c] * rng.uniform(0.8, 1.2)
        theta = thetas[c] + rng.normal(0, 0.12)
        # oriented sinusoid texture in [0,1]
        proj = np.cos(theta) * xx + np.sin(theta) * yy
        tex = 0.5 + 0.5 * np.sin(2 * np.pi * freq * proj / 4.0 + phase)
        gain = rng.uniform(0.75, 1.25)
        base = palettes[c][None, None, :] * (0.4 + 0.6 * tex[:, :, None]) * gain
        noise = rng.normal(0, 48, size=(32, 32, 3))
        images[i] = np.clip(base + noise, 0, 255).astype(np.uint8)
    return images, labels


def save_dataset(path: str, images: np.ndarray, labels: np.ndarray, nclasses: int = 10) -> None:
    """Write the PVQD container consumed by rust (rust/src/data/mod.rs)."""
    n, h, w, c = images.shape
    with open(path, "wb") as f:
        f.write(b"PVQD")
        for v in (n, h, w, c, nclasses):
            f.write(int(v).to_bytes(4, "little"))
        f.write(images.astype(np.uint8).tobytes())
        f.write(labels.astype(np.uint8).tobytes())
