"""Build-time python package: L2 jax models + L1 Pallas kernels + AOT export.

Never imported at runtime — the rust binary consumes only the artifacts.
"""
