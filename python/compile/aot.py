"""AOT build: datasets → training → weights + HLO artifacts.

Run once via ``make artifacts`` (python never runs on the request path):

  artifacts/mnist_{train,test}.bin, cifar_{train,test}.bin   datasets
  artifacts/net_{a,b,c,d}.pvqw                                f32 weights
  artifacts/net_{a,b,c,d}.hlo.txt                             float graphs
  artifacts/net_{a,c}_pallas.hlo.txt                          pallas-kernel graphs
  artifacts/net_{a,b}_pvq.hlo.txt                             PVQ-quantized graphs
  artifacts/pvq_golden.txt                                    cross-language cases
  artifacts/manifest.txt                                      geometry for rust

HLO text (not serialized protos) is the interchange — see
/opt/xla-example/README.md. Sizes/steps tunable via env:
  PVQNET_TRAIN_N / PVQNET_TEST_N / PVQNET_STEPS_MLP / PVQNET_STEPS_CNN
"""

from __future__ import annotations

import argparse
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import models
from . import pvq as pvq_mod


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the text
    parser, which is what makes xla_extension 0.5.1 accept jax ≥ 0.5
    output)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default elides baked weight tensors
    # as "{...}", which the rust-side text parser would silently turn into
    # garbage weights.
    text = comp.as_hlo_text(True)
    assert "{...}" not in text, "HLO text contains elided constants"
    return text


def save_pvqw(path: str, records: list[dict]) -> None:
    """Write the PVQW container (rust/src/nn/weights.rs)."""
    with open(path, "wb") as f:
        f.write(b"PVQW")
        f.write(struct.pack("<II", 1, len(records)))
        for r in records:
            name = r["name"].encode()
            f.write(struct.pack("<B", len(name)))
            f.write(name)
            f.write(struct.pack("<B", r["kind"]))
            f.write(struct.pack("<4I", *r["dims"]))
            w = np.asarray(r["w"], dtype=np.float32).ravel()
            b = np.asarray(r["b"], dtype=np.float32).ravel()
            f.write(struct.pack("<I", len(w)))
            f.write(w.tobytes())
            f.write(struct.pack("<I", len(b)))
            f.write(b.tobytes())


def mlp_records(params) -> list[dict]:
    recs = []
    for i, p in enumerate(params):
        out, inp = p["w"].shape
        recs.append(
            {"name": f"fc{i}", "kind": 0, "dims": (inp, out, 0, 0), "w": p["w"], "b": p["b"]}
        )
    return recs


def cnn_records(params) -> list[dict]:
    recs = []
    for i, p in enumerate(params):
        if p["w"].ndim == 4:
            kh, kw, cin, cout = p["w"].shape
            recs.append(
                {"name": f"conv{i}", "kind": 1, "dims": (kh, kw, cin, cout), "w": p["w"], "b": p["b"]}
            )
        else:
            out, inp = p["w"].shape
            recs.append(
                {"name": f"fc{i}", "kind": 0, "dims": (inp, out, 0, 0), "w": p["w"], "b": p["b"]}
            )
    return recs


# paper Tables 1-4 N/K ratios, per weighted layer
PAPER_RATIOS = {
    "a": [5.0, 5.0, 5.0],
    "b": [1.0 / 3.0, 1.0, 1.0, 1.0, 4.0, 1.0],
    "c": [2.5, 5.0, 4.0],
    "d": [0.4, 1.0, 1.5, 2.0, 5.0, 1.0],
}


def quantize_params(params, ratios):
    """The paper's §VII substitution in trained units: per layer, PVQ over
    (w ++ b) → (ρŵ, ρb̂). (The rust side additionally derives the
    integer-engine bias; for a float HLO graph ρb̂ is the exact value.)"""
    out = []
    for p, ratio in zip(params, ratios):
        wq, bq, _, rho, _ = pvq_mod.quantize_layer_weights(
            np.asarray(p["w"]), np.asarray(p["b"]), ratio
        )
        out.append({"w": jnp.asarray(wq.reshape(p["w"].shape)), "b": jnp.asarray(bq)})
    return out


def lower_mlp(params, act: str, batch: int, use_pallas: bool) -> str:
    def fn(x):
        return (models.mlp_forward(params, x, act=act, use_pallas=use_pallas),)

    spec = jax.ShapeDtypeStruct((batch, 784), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_cnn(params, act: str, batch: int) -> str:
    def fn(xflat):
        x = xflat.reshape(batch, 32, 32, 3)
        return (models.cnn_forward(params, x, act=act),)

    spec = jax.ShapeDtypeStruct((batch, 32 * 32 * 3), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def write_golden(path: str, cases: int = 40, seed: int = 1234) -> None:
    """Cross-language encoder cases: rust must reproduce exactly."""
    rng = np.random.RandomState(seed)
    with open(path, "w") as f:
        f.write("# pvq golden cases: lines = n k | v… | components… | rho\n")
        for ci in range(cases):
            n = int(rng.randint(2, 33))
            k = int(rng.randint(1, 41))
            kind = ci % 3
            if kind == 0:
                v = rng.laplace(0, 1, size=n)
            elif kind == 1:
                v = rng.normal(0, 1, size=n)
            else:
                v = rng.normal(0, 1, size=n) * (rng.uniform(size=n) < 0.5)
            q = pvq_mod.encode_fast([float(x) for x in v], k)
            f.write(f"{n} {k}\n")
            f.write(" ".join(repr(float(x)) for x in v) + "\n")
            f.write(" ".join(str(c) for c in q.components) + "\n")
            f.write(repr(q.rho) + "\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--batch", type=int, default=int(os.environ.get("PVQNET_BATCH", 32)))
    args = ap.parse_args()
    out = os.path.abspath(args.out_dir)
    os.makedirs(out, exist_ok=True)

    train_n = int(os.environ.get("PVQNET_TRAIN_N", 4000))
    test_n = int(os.environ.get("PVQNET_TEST_N", 1000))
    steps_mlp = int(os.environ.get("PVQNET_STEPS_MLP", 400))
    steps_cnn = int(os.environ.get("PVQNET_STEPS_CNN", 250))
    batch = args.batch

    manifest = []

    # ---------------- datasets
    print("== datasets")
    mtr_x, mtr_y = data_mod.synth_mnist(train_n, seed=10)
    mte_x, mte_y = data_mod.synth_mnist(test_n, seed=11)
    ctr_x, ctr_y = data_mod.synth_cifar(train_n, seed=20)
    cte_x, cte_y = data_mod.synth_cifar(test_n, seed=21)
    data_mod.save_dataset(os.path.join(out, "mnist_train.bin"), mtr_x, mtr_y)
    data_mod.save_dataset(os.path.join(out, "mnist_test.bin"), mte_x, mte_y)
    data_mod.save_dataset(os.path.join(out, "cifar_train.bin"), ctr_x, ctr_y)
    data_mod.save_dataset(os.path.join(out, "cifar_test.bin"), cte_x, cte_y)

    # ---------------- nets
    nets = {}
    for name, (fwd, act, steps, lr) in {
        "a": ("mlp", "relu", steps_mlp, 1e-3),
        "c": ("mlp", "bsign", steps_mlp, 1e-3),
        "b": ("cnn", "relu", steps_cnn, 1e-3),
        "d": ("cnn", "bsign", steps_cnn, 5e-4),
    }.items():
        print(f"== train net {name.upper()} ({fwd}, {act}, {steps} steps)")
        key = jax.random.PRNGKey({"a": 0, "b": 1, "c": 2, "d": 3}[name])
        params = models.init_mlp(key) if fwd == "mlp" else models.init_cnn(key)
        imgs, labels = (mtr_x, mtr_y) if fwd == "mlp" else (ctr_x, ctr_y)
        timgs, tlabels = (mte_x, mte_y) if fwd == "mlp" else (cte_x, cte_y)
        params, _ = models.train(params, imgs, labels, fwd, act, steps=steps, lr=lr)
        acc = models.evaluate(params, timgs, tlabels, fwd, act)
        print(f"   test accuracy (normalized-input convention): {acc:.4f}")
        # .pvqw keeps *trained-unit* params (the rust ModelSpec carries an
        # explicit Scale(1/255) layer); HLO graphs get the scale folded in
        # so they consume raw pixels directly.
        nets[name] = {"params": params, "fwd": fwd, "act": act, "acc": acc}
        recs = mlp_records(params) if fwd == "mlp" else cnn_records(params)
        save_pvqw(os.path.join(out, f"net_{name}.pvqw"), recs)
        manifest.append(f"net_{name}.acc {acc:.4f}")

    # ---------------- HLO lowering (raw-pixel inputs: fold 1/255 in)
    print("== lower HLO")
    for name, net in nets.items():
        net["raw_params"] = models.fold_input_scale(net["params"], 255.0)
        if net["fwd"] == "mlp":
            hlo = lower_mlp(net["raw_params"], net["act"], batch, use_pallas=False)
            ilen, olen = 784, 10
        else:
            hlo = lower_cnn(net["raw_params"], net["act"], batch)
            ilen, olen = 32 * 32 * 3, 10
        p = os.path.join(out, f"net_{name}.hlo.txt")
        open(p, "w").write(hlo)
        manifest.append(f"net_{name}.hlo net_{name}.hlo.txt {batch} {ilen} {olen}")
        print(f"   net_{name}.hlo.txt ({len(hlo)} chars)")

    # pallas-kernel variants (the L1 kernel lowered into the same HLO)
    for name in ("a", "c"):
        net = nets[name]
        hlo = lower_mlp(net["raw_params"], net["act"], batch, use_pallas=True)
        p = os.path.join(out, f"net_{name}_pallas.hlo.txt")
        open(p, "w").write(hlo)
        manifest.append(f"net_{name}_pallas.hlo net_{name}_pallas.hlo.txt {batch} 784 10")
        print(f"   net_{name}_pallas.hlo.txt ({len(hlo)} chars)")

    # PVQ-quantized variants at paper ratios (weights baked quantized)
    for name in ("a", "b"):
        net = nets[name]
        qparams = quantize_params(net["params"], PAPER_RATIOS[name])
        qraw = models.fold_input_scale(qparams, 255.0)
        if net["fwd"] == "mlp":
            hlo = lower_mlp(qraw, net["act"], batch, use_pallas=False)
            ilen = 784
        else:
            hlo = lower_cnn(qraw, net["act"], batch)
            ilen = 32 * 32 * 3
        p = os.path.join(out, f"net_{name}_pvq.hlo.txt")
        open(p, "w").write(hlo)
        manifest.append(f"net_{name}_pvq.hlo net_{name}_pvq.hlo.txt {batch} {ilen} 10")
        print(f"   net_{name}_pvq.hlo.txt ({len(hlo)} chars)")

    # ---------------- golden cases + manifest
    write_golden(os.path.join(out, "pvq_golden.txt"))
    with open(os.path.join(out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print("== done:", out)


if __name__ == "__main__":
    sys.exit(main())
