"""Pallas kernel: batched pyramid projection (PVQ encoding, data-parallel
half).

Row-wise over a [B, N] block: t = K·|v|/‖v‖₁, y = ⌊t + ½⌋. This is the
O(N) part of the author's O(NK) CUDA encoder (§VII) re-thought for TPU:
rows are independent lanes, the reduction is a VMEM-resident row sum.
The ±pulse correction (expected O(√N) fixups per row) stays on the host
(or in rust) — it is sequential and negligible.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEF_ROWS = 8  # rows per grid step


def _kernel(v_ref, k_ref, y_ref, s_ref):
    v = v_ref[...]
    av = jnp.abs(v)
    l1 = jnp.sum(av, axis=-1, keepdims=True)
    k = k_ref[0].astype(jnp.float32)
    t = jnp.where(l1 > 0, k * av / l1, 0.0)
    y = jnp.floor(t + 0.5)
    y_ref[...] = y
    s_ref[...] = jnp.sum(y, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("rows",))
def pvq_project(v, k, *, rows: int = DEF_ROWS):
    """Project each row of v [B, N] onto P(N, k) magnitudes (pre-correction).

    Returns (y [B, N] f32 magnitudes, sums [B] i32). The full vector on
    the pyramid is sign(v)·y after the host-side pulse correction.
    """
    B, N = v.shape
    rows_ = min(rows, B)
    Bp = -(-B // rows_) * rows_
    vp = jnp.pad(v.astype(jnp.float32), ((0, Bp - B), (0, 0)))
    k_arr = jnp.asarray([k], dtype=jnp.int32)
    y, s = pl.pallas_call(
        _kernel,
        grid=(Bp // rows_,),
        in_specs=[
            pl.BlockSpec((rows_, N), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((rows_, N), lambda i: (i, 0)),
            pl.BlockSpec((rows_,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, N), jnp.float32),
            jax.ShapeDtypeStruct((Bp,), jnp.int32),
        ],
        interpret=True,
    )(vp, k_arr)
    return y[:B], s[:B]
