"""Pallas kernel: PVQ dense layer  y = (x @ ŵᵀ)·ρ + b.

TPU adaptation of the paper's §III dot-product trick (docs/ARCHITECTURE.md
§2): on a systolic-array machine the win is not
add-vs-mult — the MXU does fused MACs — but *weight bandwidth*: PVQ
weights are tiny integers (Tables 5–8: ≥97 % in {0,±1,±2,±3}), so ŵ ships
HBM→VMEM as int8 (4× less traffic than f32) and is upcast in-register
right before the MXU dot; ρ is one scalar multiply per tile.

Grid layout: (B/bm, M/bn, N/bk), K-innermost so each (i,j) output tile
stays resident in VMEM while the kernel marches over the contraction —
the BlockSpec index maps express the HBM→VMEM schedule the paper's FPGA
designs express with serial accumulators.

interpret=True everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; real-TPU perf is estimated analytically in docs/ARCHITECTURE.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default tiles; shrunk automatically for small operands.
DEF_BM, DEF_BN, DEF_BK = 128, 128, 512


def _kernel(x_ref, w_ref, b_ref, rho_ref, o_ref, *, nk: int):
    """One (bm × bn) output tile; k = program_id(2) marches over N."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # int8 weights upcast in-register (VMEM→register dequant, no extra
    # HBM traffic) — on TPU this feeds the MXU as bf16/f32.
    acc = jnp.dot(
        x_ref[...],
        w_ref[...].astype(jnp.float32).T,
        preferred_element_type=jnp.float32,
    )
    o_ref[...] += acc

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = o_ref[...] * rho_ref[0] + b_ref[...][None, :]


def _pick(tile: int, dim: int) -> int:
    return min(tile, dim)


def _pvq_matmul_impl(x, w_int, b, rho, *, bm: int, bn: int, bk: int):
    B, N = x.shape
    M, N2 = w_int.shape
    assert N == N2, f"contraction mismatch {N} vs {N2}"
    assert b.shape == (M,)

    bm_, bn_, bk_ = _pick(bm, B), _pick(bn, M), _pick(bk, N)
    Bp, Mp, Np = -(-B // bm_) * bm_, -(-M // bn_) * bn_, -(-N // bk_) * bk_
    xp = jnp.pad(x.astype(jnp.float32), ((0, Bp - B), (0, Np - N)))
    wp = jnp.pad(w_int, ((0, Mp - M), (0, Np - N)))
    bp = jnp.pad(b.astype(jnp.float32), (0, Mp - M))
    rho_arr = jnp.asarray([rho], dtype=jnp.float32)

    nk = Np // bk_
    grid = (Bp // bm_, Mp // bn_, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),  # x tile
            pl.BlockSpec((bn_, bk_), lambda i, j, k: (j, k)),  # ŵ tile
            pl.BlockSpec((bn_,), lambda i, j, k: (j,)),  # bias tile
            pl.BlockSpec((1,), lambda i, j, k: (0,)),  # ρ
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Mp), jnp.float32),
        interpret=True,
    )(xp, wp, bp, rho_arr)
    return out[:B, :M]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _pvq_matmul_vjp(x, w_int, b, rho, bm, bn, bk):
    return _pvq_matmul_impl(x, w_int, b, rho, bm=bm, bn=bn, bk=bk)


def _fwd(x, w_int, b, rho, bm, bn, bk):
    y = _pvq_matmul_impl(x, w_int, b, rho, bm=bm, bn=bn, bk=bk)
    return y, (x, w_int, b, rho)


def _bwd(bm, bn, bk, res, g):
    # Hand-written VJP: pallas_call with accumulating grids is not
    # reverse-differentiable in this jax version, and training needs the
    # gradient path when the kernel backs L2 dense layers. Integer ŵ is a
    # frozen constant by construction → float0 cotangent.
    import numpy as _np

    x, w_int, b, rho = res
    wf = w_int.astype(jnp.float32)
    dx = (g @ wf) * rho
    if jnp.issubdtype(w_int.dtype, jnp.floating):
        dw = (rho * (g.T @ x)).astype(w_int.dtype)
    else:
        dw = _np.zeros(w_int.shape, dtype=jax.dtypes.float0)
    db = jnp.sum(g, axis=0)
    drho = jnp.sum(g * (x @ wf.T)).astype(jnp.float32)
    return dx, dw, db, drho


_pvq_matmul_vjp.defvjp(_fwd, _bwd)


def pvq_matmul(x, w_int, b, rho, *, bm: int = DEF_BM, bn: int = DEF_BN, bk: int = DEF_BK):
    """y = (x @ ŵᵀ)·ρ + b with ŵ in a compact integer dtype.

    x: [B, N] f32; w_int: [M, N] int8/int32 (integer-valued) or f32;
    b: [M] f32; rho: scalar. Shapes need not be tile-aligned — inputs are
    zero-padded to the tile grid (zero rows/cols contribute nothing).
    Differentiable via a hand-written VJP (w gradient defined only for
    float weight dtypes; integer ŵ is a frozen constant by construction).
    """
    rho = jnp.asarray(rho, dtype=jnp.float32)
    return _pvq_matmul_vjp(x, w_int, b, rho, bm, bn, bk)


def vmem_footprint_bytes(bm: int, bn: int, bk: int, w_dtype_bytes: int = 1) -> int:
    """Analytic VMEM footprint of one grid step (docs/ARCHITECTURE.md):
    x tile (f32) + ŵ tile (int8) + out tile (f32) + bias."""
    return bm * bk * 4 + bn * bk * w_dtype_bytes + bm * bn * 4 + bn * 4


def mxu_utilization_estimate(bm: int, bn: int, bk: int) -> float:
    """Fraction of 128×128 MXU lanes a tile shape keeps busy."""
    return min(bm / 128.0, 1.0) * min(bn / 128.0, 1.0)
