"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest + hypothesis sweep shapes
and dtypes and require the kernels to match these to float tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp


def pvq_matmul_ref(x, w_int, b, rho):
    """y = (x @ ŵᵀ)·ρ + b.

    x: [B, N] f32 activations
    w_int: [M, N] integer-valued PVQ weights (stored int8/int32/f32)
    b: [M] f32 bias (already ρ-scaled by the quantizer)
    rho: scalar gain
    """
    return jnp.dot(x, w_int.astype(jnp.float32).T) * rho + b[None, :]


def pvq_project_ref(v, k):
    """Row-wise pyramid prescale: t = K·|v| / ‖v‖₁, y = ⌊t + ½⌋.

    Returns (y_magnitudes f32 [B, N], sum_y i32 [B]) — the data-parallel
    half of PVQ encoding; the ±1-pulse correction is a short host-side
    loop over the O(√N) residual (see aot.py / rust encode_fast).
    Zero rows project to zero.
    """
    av = jnp.abs(v)
    l1 = jnp.sum(av, axis=-1, keepdims=True)
    t = jnp.where(l1 > 0, k * av / l1, 0.0)
    y = jnp.floor(t + 0.5)
    return y, jnp.sum(y, axis=-1).astype(jnp.int32)
