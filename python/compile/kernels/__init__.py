"""L1 Pallas kernels (interpret=True) + jnp reference oracles."""

from . import ref  # noqa: F401
from .pvq_matmul import pvq_matmul  # noqa: F401
from .pvq_project import pvq_project  # noqa: F401
