"""L2 model tests: shapes, STE gradients, training smoke, export identities."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import models
from compile.data import synth_cifar, synth_mnist


def test_mlp_shapes():
    params = models.init_mlp(jax.random.PRNGKey(0))
    x = jnp.zeros((4, 784))
    out = models.mlp_forward(params, x)
    assert out.shape == (4, 10)


def test_cnn_shapes_match_paper_table2():
    params = models.init_cnn(jax.random.PRNGKey(0))
    # paper Table 2 param counts per layer
    counts = [int(np.prod(p["w"].shape)) + int(np.prod(p["b"].shape)) for p in params]
    assert counts == [896, 9248, 18496, 36928, 2097664, 5130]
    x = jnp.zeros((2, 32, 32, 3))
    out = models.cnn_forward(params, x)
    assert out.shape == (2, 10)


def test_bsign_values_and_ste_grad():
    x = jnp.asarray([-2.0, -0.0, 0.0, 3.5])
    y = models.bsign(x)
    np.testing.assert_array_equal(np.asarray(y), [-1.0, 1.0, 1.0, 1.0])
    # STE: gradient passes through as identity (eq. 18)
    g = jax.grad(lambda v: jnp.sum(models.bsign(v) * jnp.asarray([1.0, 2.0, 3.0, 4.0])))(x)
    np.testing.assert_array_equal(np.asarray(g), [1.0, 2.0, 3.0, 4.0])


def test_bsign_mlp_forward_pm1_hidden():
    params = models.init_mlp(jax.random.PRNGKey(1), sizes=(16, 8, 4))
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 16))
    h = models._act(models.dense_apply(params[0], x, False), "bsign")
    assert set(np.unique(np.asarray(h))) <= {-1.0, 1.0}


def test_fold_input_scale_identity():
    """model(x/255, params) == model(x, fold(params, 255)) exactly at f32."""
    params = models.init_mlp(jax.random.PRNGKey(3), sizes=(12, 6, 4))
    x = jnp.asarray(np.random.RandomState(0).randint(0, 256, size=(5, 12)).astype(np.float32))
    a = models.mlp_forward(params, x / 255.0)
    b = models.mlp_forward(models.fold_input_scale(params, 255.0), x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_training_reduces_loss_mlp():
    imgs, labels = synth_mnist(600, seed=1)
    params = models.init_mlp(jax.random.PRNGKey(4))
    params, hist = models.train(params, imgs, labels, "mlp", "relu", steps=60, log_every=59)
    assert hist[-1][1] < hist[0][1], f"loss did not drop: {hist}"
    acc = models.evaluate(params, imgs, labels, "mlp", "relu")
    assert acc > 0.3, f"train accuracy {acc}"


def test_training_bsign_learns():
    imgs, labels = synth_mnist(600, seed=2)
    params = models.init_mlp(jax.random.PRNGKey(5))
    params, hist = models.train(params, imgs, labels, "mlp", "bsign", steps=60, log_every=59)
    assert hist[-1][1] < hist[0][1]


def test_cnn_train_smoke():
    imgs, labels = synth_cifar(200, seed=3)
    params = models.init_cnn(jax.random.PRNGKey(6))
    params, hist = models.train(params, imgs, labels, "cnn", "relu", steps=8, batch=16, log_every=7)
    assert np.isfinite(hist[-1][1])


def test_pallas_dense_path_matches_jnp():
    params = models.init_mlp(jax.random.PRNGKey(7), sizes=(20, 12, 4))
    x = jax.random.normal(jax.random.PRNGKey(8), (6, 20))
    a = models.mlp_forward(params, x, use_pallas=False)
    b = models.mlp_forward(params, x, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)
