"""PVQ encoder invariants (python reference implementation)."""

import math

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.pvq import encode_fast, quantize_layer_weights


@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(1, 64),
    k=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
def test_on_pyramid(n, k, seed):
    rng = np.random.RandomState(seed)
    v = [float(x) for x in rng.laplace(0, 1, size=n)]
    q = encode_fast(v, k)
    assert q.is_valid()
    assert len(q.components) == n
    # signs follow input
    for x, c in zip(v, q.components):
        if c != 0:
            assert (x < 0) == (c < 0)


def test_zero_vector_and_zero_k():
    q = encode_fast([0.0, 0.0], 5)
    assert q.rho == 0.0 and q.components == [0, 0]
    q = encode_fast([1.0, -2.0], 0)
    assert q.rho == 0.0


def test_norm_rho_preserves_radius():
    rng = np.random.RandomState(1)
    v = [float(x) for x in rng.normal(size=32)]
    q = encode_fast(v, 16)
    rv = math.sqrt(sum(x * x for x in v))
    rd = math.sqrt(sum(x * x for x in q.decode()))
    assert abs(rv - rd) < 1e-9


def test_error_monotone_in_k():
    rng = np.random.RandomState(2)
    v = [float(x) for x in rng.laplace(size=24)]
    last = float("inf")
    for k in (1, 2, 4, 8, 16, 32, 64, 128):
        q = encode_fast(v, k, rho_mode="lsq")
        mse = sum((a - b) ** 2 for a, b in zip(v, q.decode())) / len(v)
        assert mse <= last + 1e-12
        last = mse


def test_sparsity_guarantee_at_ratio_5():
    """§VI: N/K=5 ⇒ ≥ 4/5 zeros."""
    rng = np.random.RandomState(3)
    n = 5000
    v = [float(x) for x in rng.laplace(size=n)]
    q = encode_fast(v, n // 5)
    zeros = sum(1 for c in q.components if c == 0)
    assert zeros * 5 >= 4 * n - 5


def test_quantize_layer_weights_roundtrip():
    rng = np.random.RandomState(4)
    w = rng.laplace(0, 0.2, size=(16, 32)).astype(np.float32)
    b = rng.laplace(0, 0.05, size=16).astype(np.float32)
    wq, bq, comps, rho, k = quantize_layer_weights(w, b, ratio=2.0)
    n = w.size + b.size
    assert k == max(1, round(n / 2.0))
    assert abs(comps).sum() == k
    assert wq.shape == (w.size,)
    assert bq.shape == (16,)
    # float-equivalent weights = rho * integer components
    np.testing.assert_allclose(wq, rho * comps[: w.size], rtol=1e-6)


def test_bias_input_scale():
    """With input_scale s, the encoded vector sees b/s but the substituted
    bias is ρ·s·b̂ — consistency identity."""
    rng = np.random.RandomState(5)
    w = rng.laplace(0, 0.2, size=(8, 8)).astype(np.float32)
    b = rng.laplace(0, 0.1, size=8).astype(np.float32)
    s = 0.37
    wq, bq, comps, rho, k = quantize_layer_weights(w, b, ratio=1.0, input_scale=s)
    np.testing.assert_allclose(bq, rho * s * comps[w.size:], rtol=1e-6)
