"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

hypothesis sweeps shapes; tolerances are float32-tight. This is the CORE
kernel correctness signal — the same kernels lower into the HLO the rust
runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.pvq_matmul import (
    mxu_utilization_estimate,
    pvq_matmul,
    vmem_footprint_bytes,
)
from compile.kernels.pvq_project import pvq_project
from compile.kernels.ref import pvq_matmul_ref, pvq_project_ref


def _pvq_like_weights(rng, m, n):
    """Integer weights with PVQ-ish statistics (mostly 0/±1)."""
    probs = rng.uniform(size=(m, n))
    w = np.zeros((m, n), dtype=np.int8)
    w[probs > 0.6] = 1
    w[probs > 0.8] = -1
    w[probs > 0.95] = 2
    w[probs > 0.98] = -3
    return w


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 17),
    m=st.integers(1, 40),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_pvq_matmul_matches_ref(b, m, n, seed):
    rng = np.random.RandomState(seed)
    x = rng.normal(0, 1, size=(b, n)).astype(np.float32)
    w = _pvq_like_weights(rng, m, n)
    bias = rng.normal(0, 0.1, size=(m,)).astype(np.float32)
    rho = float(rng.uniform(0.01, 2.0))
    got = pvq_matmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), rho)
    want = pvq_matmul_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), rho)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int32, jnp.float32])
def test_pvq_matmul_weight_dtypes(dtype):
    rng = np.random.RandomState(0)
    x = rng.normal(size=(4, 30)).astype(np.float32)
    w = _pvq_like_weights(rng, 8, 30).astype(np.asarray(jnp.zeros(1, dtype)).dtype)
    bias = np.zeros(8, dtype=np.float32)
    got = pvq_matmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), 0.5)
    want = pvq_matmul_ref(jnp.asarray(x), jnp.asarray(w, dtype=jnp.float32), jnp.asarray(bias), 0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_pvq_matmul_tile_aligned_and_tiny_tiles():
    rng = np.random.RandomState(1)
    x = rng.normal(size=(8, 64)).astype(np.float32)
    w = _pvq_like_weights(rng, 16, 64)
    bias = rng.normal(size=(16,)).astype(np.float32)
    want = np.asarray(pvq_matmul_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), 1.3))
    for bm, bn, bk in [(8, 16, 64), (4, 8, 16), (2, 2, 8)]:
        got = np.asarray(
            pvq_matmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(bias), 1.3, bm=bm, bn=bn, bk=bk)
        )
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_pvq_matmul_grad_flows():
    """The kernel participates in jax autodiff (training-path usability)."""
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.normal(size=(3, 10)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(5, 10)).astype(np.float32))
    bias = jnp.zeros(5, dtype=jnp.float32)

    def loss(xx):
        return jnp.sum(pvq_matmul(xx, w, bias, 1.0) ** 2)

    g = jax.grad(loss)(x)
    ref = jax.grad(lambda xx: jnp.sum(pvq_matmul_ref(xx, w, bias, 1.0) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(ref), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 12),
    n=st.integers(1, 100),
    k=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_pvq_project_matches_ref(b, n, k, seed):
    rng = np.random.RandomState(seed)
    v = rng.laplace(0, 1, size=(b, n)).astype(np.float32)
    y, s = pvq_project(jnp.asarray(v), k)
    yr, sr = pvq_project_ref(jnp.asarray(v), k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


def test_pvq_project_zero_rows():
    v = jnp.zeros((3, 16), dtype=jnp.float32)
    y, s = pvq_project(v, 10)
    assert np.all(np.asarray(y) == 0)
    assert np.all(np.asarray(s) == 0)


def test_pvq_project_sum_near_k():
    """Pre-correction pulse sums land within O(√N) of K (the correction
    the host performs is small — that is why it stays off the TPU)."""
    rng = np.random.RandomState(3)
    v = rng.laplace(0, 1, size=(16, 400)).astype(np.float32)
    k = 100
    _, s = pvq_project(jnp.asarray(v), k)
    dev = np.abs(np.asarray(s) - k)
    # each of the N components contributes < 1/2 rounding error; in
    # practice the deviation is a small fraction of K
    assert dev.max() <= 80, f"max |Σy−K| = {dev.max()}"


def test_vmem_and_mxu_estimates():
    # default tiles fit comfortably in 16 MiB VMEM and fill the MXU
    assert vmem_footprint_bytes(128, 128, 512) < 16 << 20
    assert mxu_utilization_estimate(128, 128, 512) == 1.0
    assert mxu_utilization_estimate(64, 128, 512) == 0.5
