"""Dataset generator tests + container format checks."""

import os
import struct
import tempfile

import numpy as np

from compile.data import save_dataset, synth_cifar, synth_mnist


def test_mnist_shapes_determinism():
    x, y = synth_mnist(40, seed=5)
    assert x.shape == (40, 28, 28, 1) and x.dtype == np.uint8
    assert y.shape == (40,) and set(np.unique(y)) <= set(range(10))
    x2, _ = synth_mnist(40, seed=5)
    np.testing.assert_array_equal(x, x2)
    x3, _ = synth_mnist(40, seed=6)
    assert not np.array_equal(x, x3)


def test_cifar_shapes_and_class_signal():
    x, y = synth_cifar(60, seed=7)
    assert x.shape == (60, 32, 32, 3) and x.dtype == np.uint8
    # class pairs deliberately SHARE palettes (color alone must not solve
    # the task); the class signal is texture. Check palette groups differ
    # across pairs while texture frequency separates within a pair.
    means = np.stack([x[y == c].mean(axis=(0, 1, 2)) for c in range(10)])
    # classes 0 and 2 use different palettes
    assert np.linalg.norm(means[0] - means[2]) > 4.0
    # classes 0 and 1 share a palette → color means are close…
    assert np.linalg.norm(means[0] - means[1]) < 25.0  # gain jitter adds spread
    # …and the texture carries real structure (not flat noise). The
    # class-separability of the texture signal itself is asserted
    # end-to-end by net B/D reaching far-above-chance accuracy in the
    # rust integration suite (broadband noise masks simple spectral
    # statistics here by design).
    assert x.astype(np.float32).std() > 20.0


def test_container_layout():
    x, y = synth_mnist(7, seed=8)
    with tempfile.TemporaryDirectory() as td:
        p = os.path.join(td, "d.bin")
        save_dataset(p, x, y)
        raw = open(p, "rb").read()
        assert raw[:4] == b"PVQD"
        n, h, w, c, ncls = struct.unpack("<5I", raw[4:24])
        assert (n, h, w, c, ncls) == (7, 28, 28, 1, 10)
        assert len(raw) == 24 + 7 * 28 * 28 + 7
        # pixel payload matches
        pix = np.frombuffer(raw[24 : 24 + 7 * 784], dtype=np.uint8).reshape(7, 28, 28, 1)
        np.testing.assert_array_equal(pix, x)


def test_glyphs_brightness():
    x, _ = synth_mnist(20, seed=9)
    for i in range(20):
        assert (x[i] >= 150).sum() > 50, f"sample {i} lacks glyph signal"
