#!/usr/bin/env bash
# Tier-1 verification + doc gate + lint gate. Run from anywhere; executes in rust/.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== cargo doc --no-deps"
# broken intra-doc links are denied in lib.rs (rustdoc::broken_intra_doc_links)
cargo doc --no-deps

echo "== cargo test --doc -q"
# runnable doc-examples (pvq::encode, artifact, nn::batch, …) must stay green
cargo test --doc -q

echo "== cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "verify.sh: all green"
