#!/usr/bin/env bash
# Tier-1 verification + lint gate. Run from anywhere; executes in rust/.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "verify.sh: all green"
