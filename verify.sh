#!/usr/bin/env bash
# The repository's single verification entrypoint: fmt gate + tier-1
# build/tests + doc gate + lint gate. Run from anywhere; executes in
# rust/. CI (.github/workflows/ci.yml) invokes this same script, so the
# local gate and the CI gate cannot drift.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo fmt --check"
cargo fmt --all -- --check

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== cargo bench --no-run"
# the bench harness (measured protocol + BENCH_*.json emitters) must
# always compile, even though verify never runs a measured sweep
cargo bench --no-run

echo "== cargo doc --no-deps"
# broken intra-doc links are denied in lib.rs (rustdoc::broken_intra_doc_links)
cargo doc --no-deps

echo "== cargo test --doc -q"
# runnable doc-examples (pvq::encode, artifact, nn::batch, nn::parallel, …)
cargo test --doc -q

echo "== cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets -- -D warnings

echo "verify.sh: all green"
